//! `flopt explain` diagnostics: span-anchored dependence reports.
//!
//! [`explain_program`] runs the engine over every loop of a program and
//! packages the verdicts, dependence facts, optimistic notes, and test
//! counters into an [`ExplainReport`].  The report renders two ways —
//! human text ([`ExplainReport::render`]) and a JSON document
//! ([`ExplainReport::to_json`]) — and both are deterministic byte
//! streams so the serve cache can store the pair as one artifact and
//! return byte-identical answers warm or cold, at any pool width.

use std::collections::BTreeMap;

use crate::cparse::ast::LoopId;
use crate::cparse::error::Pos;
use crate::cparse::{pretty, Program};
use crate::ir::loops::LoopInfo;
use crate::ir::{loops, varref};
use crate::util::intern::Symbol;
use crate::util::json::{self, Json};

use super::{engine, LoopDeps, LoopVerdict};

/// Engine output for one loop, anchored to its source span.
#[derive(Debug, Clone)]
pub struct LoopExplain {
    /// Loop id (`L0`, `L1`, … in extraction order).
    pub id: LoopId,
    /// Enclosing function.
    pub function: Symbol,
    /// Source position of the loop statement.
    pub pos: Pos,
    /// Full engine output.
    pub deps: LoopDeps,
}

/// Dependence diagnostics for every loop of one application.
#[derive(Debug, Clone)]
pub struct ExplainReport {
    /// Application name.
    pub app: String,
    /// Per-loop diagnostics in extraction order.
    pub loops: Vec<LoopExplain>,
}

/// The cacheable artifact: both renderings of one report.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExplainArtifact {
    /// Human-readable rendering.
    pub text: String,
    /// JSON rendering (one serialized document).
    pub json: String,
}

/// Run the dependence engine over every loop of `program`.
pub fn explain_program(app: &str, program: &Program) -> ExplainReport {
    let mut out = Vec::new();
    for info in loops::extract(program) {
        let refs = varref::collect(&info);
        let deps = engine::analyze_loop(&info, &refs);
        out.push(explain_one(&info, deps));
    }
    ExplainReport { app: app.to_string(), loops: out }
}

fn explain_one(info: &LoopInfo, deps: LoopDeps) -> LoopExplain {
    LoopExplain { id: info.id, function: info.function, pos: info.pos, deps }
}

impl ExplainReport {
    /// Render both artifact forms.
    pub fn artifact(&self) -> ExplainArtifact {
        ExplainArtifact { text: self.render(), json: json::to_string(&self.to_json()) }
    }

    /// Human-readable diagnostics.
    pub fn render(&self) -> String {
        let mut s = format!("=== explain: {} ===\n", self.app);
        for l in &self.loops {
            let d = &l.deps;
            s.push_str(&format!("{} in {} @{}: {}", l.id, l.function, l.pos, d.verdict.tag()));
            match &d.verdict {
                LoopVerdict::Sequential(r) | LoopVerdict::Unknown(r) => {
                    s.push_str(&format!(" -- {r}"));
                }
                _ => {}
            }
            s.push('\n');
            if !d.reductions.is_empty() {
                let vars: Vec<String> =
                    d.reductions.iter().map(|r| format!("{}({})", r.var, r.op)).collect();
                s.push_str(&format!("  reductions: {}\n", vars.join(" ")));
            }
            for dep in &d.deps {
                s.push_str(&format!(
                    "  dep: {} on {}: {} vs {} [{}]\n",
                    dep.class.as_str(),
                    dep.array,
                    pretty::expr(&dep.source),
                    pretty::expr(&dep.sink),
                    dep.test
                ));
            }
            for n in &d.notes {
                let subs: Vec<String> = n.subscripts.iter().map(|e| pretty::expr(e)).collect();
                s.push_str(&format!(
                    "  note: {} on {}: {}\n",
                    n.kind.as_str(),
                    n.array,
                    subs.join(", ")
                ));
            }
            if !d.tests.is_empty() {
                let counts: Vec<String> =
                    d.tests.iter().map(|(t, c)| format!("{t}={c}")).collect();
                s.push_str(&format!("  tests: {}\n", counts.join(" ")));
            }
        }
        s
    }

    /// JSON document (sorted object keys, deterministic).
    pub fn to_json(&self) -> Json {
        let mut doc = BTreeMap::new();
        doc.insert("app".to_string(), Json::Str(self.app.clone()));
        let mut loops = Vec::new();
        for l in &self.loops {
            let d = &l.deps;
            let mut o = BTreeMap::new();
            o.insert("id".to_string(), Json::Str(l.id.to_string()));
            o.insert("function".to_string(), Json::Str(l.function.to_string()));
            o.insert("pos".to_string(), Json::Str(l.pos.to_string()));
            o.insert("verdict".to_string(), Json::Str(d.verdict.tag().to_string()));
            o.insert(
                "reason".to_string(),
                match d.verdict.reject_reason() {
                    Some(r) => Json::Str(r.to_string()),
                    None => Json::Null,
                },
            );
            o.insert("offloadable".to_string(), Json::Bool(d.offloadable()));
            let reds = d
                .reductions
                .iter()
                .map(|r| {
                    let mut ro = BTreeMap::new();
                    ro.insert("var".to_string(), Json::Str(r.var.to_string()));
                    ro.insert("op".to_string(), Json::Str(r.op.to_string()));
                    Json::Obj(ro)
                })
                .collect();
            o.insert("reductions".to_string(), Json::Arr(reds));
            let deps = d
                .deps
                .iter()
                .map(|dep| {
                    let mut dobj = BTreeMap::new();
                    dobj.insert("class".to_string(), Json::Str(dep.class.as_str().to_string()));
                    dobj.insert("array".to_string(), Json::Str(dep.array.to_string()));
                    dobj.insert("source".to_string(), Json::Str(pretty::expr(&dep.source)));
                    dobj.insert(
                        "source_pos".to_string(),
                        Json::Str(dep.source.pos.to_string()),
                    );
                    dobj.insert("sink".to_string(), Json::Str(pretty::expr(&dep.sink)));
                    dobj.insert("sink_pos".to_string(), Json::Str(dep.sink.pos.to_string()));
                    dobj.insert("test".to_string(), Json::Str(dep.test.to_string()));
                    Json::Obj(dobj)
                })
                .collect();
            o.insert("deps".to_string(), Json::Arr(deps));
            let notes = d
                .notes
                .iter()
                .map(|n| {
                    let mut nobj = BTreeMap::new();
                    nobj.insert("kind".to_string(), Json::Str(n.kind.as_str().to_string()));
                    nobj.insert("array".to_string(), Json::Str(n.array.to_string()));
                    nobj.insert(
                        "subscripts".to_string(),
                        Json::Arr(
                            n.subscripts.iter().map(|e| Json::Str(pretty::expr(e))).collect(),
                        ),
                    );
                    Json::Obj(nobj)
                })
                .collect();
            o.insert("notes".to_string(), Json::Arr(notes));
            let mut tobj = BTreeMap::new();
            for (t, c) in &d.tests {
                tobj.insert(t.to_string(), Json::Num(f64::from(*c)));
            }
            o.insert("tests".to_string(), Json::Obj(tobj));
            loops.push(Json::Obj(o));
        }
        doc.insert("loops".to_string(), Json::Arr(loops));
        Json::Obj(doc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cparse::parse;

    const SRC: &str = "void f(float a[], float out[], int n) { int i; float s; s = 0.0; \
         for (i = 1; i < n; i++) { out[i] = a[i - 1]; } \
         for (i = 0; i < n; i++) { s += a[i]; } }";

    #[test]
    fn report_covers_every_loop_in_order() {
        let p = parse(SRC).unwrap();
        let r = explain_program("demo", &p);
        assert_eq!(r.loops.len(), 2);
        assert_eq!(r.loops[0].id.to_string(), "L0");
        assert!(r.loops[0].deps.offloadable());
        assert!(matches!(r.loops[1].deps.verdict, LoopVerdict::Reduction(_)));
    }

    #[test]
    fn render_names_test_and_subscripts_for_a_dep() {
        let p = parse(
            "void f(float a[], int n) { int i; \
             for (i = 1; i < n; i++) { a[i] = a[i - 1]; } }",
        )
        .unwrap();
        let r = explain_program("rec", &p).render();
        assert!(r.contains("sequential -- array read/write index mismatch"), "{r}");
        assert!(r.contains("dep: flow/anti on a: a[i] vs a[(i - 1)] [siv-strong]"), "{r}");
    }

    #[test]
    fn json_roundtrips_and_anchors_spans() {
        let p = parse(SRC).unwrap();
        let rep = explain_program("demo", &p);
        let text = json::to_string(&rep.to_json());
        let doc = json::parse(&text).unwrap();
        assert_eq!(doc.get("app").and_then(|j| j.as_str()), Some("demo"));
        let loops = doc.get("loops").and_then(|j| j.as_arr()).unwrap();
        assert_eq!(loops.len(), 2);
        let pos = loops[0].get("pos").and_then(|j| j.as_str()).unwrap();
        assert!(pos.contains(':'), "span is line:col, got {pos}");
    }

    #[test]
    fn artifact_is_deterministic() {
        let p = parse(SRC).unwrap();
        let a1 = explain_program("demo", &p).artifact();
        let a2 = explain_program("demo", &p).artifact();
        assert_eq!(a1, a2);
    }
}
