//! Affine subscript forms over a loop counter.
//!
//! A subscript expression is abstracted as `a·i + c + Σ coeffₖ·termₖ`
//! where `i` is the loop counter, `c` a constant, and each symbolic
//! term a *product* of non-counter variables (so `b * span + j` and
//! `NP - 1 - i` both stay exact).  Anything the grammar cannot express
//! affinely — a counter multiplied by a non-constant, a division, a
//! call — has no form, and the engine falls back to its conservative
//! or optimistic tiers.

use std::collections::{BTreeMap, BTreeSet};

use crate::cparse::ast::{BinOp, Expr, ExprKind, UnOp};
use crate::ir::CanonicalLoop;
use crate::util::intern::Symbol;

/// Affine form of one subscript in a given loop counter.
///
/// Symbolic term keys are sorted products of interned [`Symbol`]s, so
/// `b*span` and `span*b` collapse to one term and comparison is exact.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct LinearForm {
    /// Coefficient of the loop counter.
    pub a: i64,
    /// Constant part.
    pub c: i64,
    /// Symbolic part: sorted product-of-symbols key → coefficient.
    pub terms: BTreeMap<Vec<Symbol>, i64>,
}

impl LinearForm {
    /// The constant form `c`.
    pub fn constant(c: i64) -> LinearForm {
        LinearForm { a: 0, c, terms: BTreeMap::new() }
    }

    /// Is this form free of both the counter and symbolic terms?
    pub fn is_const(&self) -> bool {
        self.a == 0 && self.terms.is_empty()
    }

    /// Every symbol mentioned by a symbolic term.
    pub fn syms(&self) -> BTreeSet<Symbol> {
        self.terms.keys().flatten().copied().collect()
    }

    fn normalized(mut self) -> LinearForm {
        self.terms.retain(|_, v| *v != 0);
        self
    }

    /// `self + r`.
    pub fn add(&self, r: &LinearForm) -> LinearForm {
        let mut terms = self.terms.clone();
        for (k, v) in &r.terms {
            *terms.entry(k.clone()).or_insert(0) += v;
        }
        LinearForm { a: self.a + r.a, c: self.c + r.c, terms }.normalized()
    }

    /// `-self`.
    pub fn neg(&self) -> LinearForm {
        LinearForm {
            a: -self.a,
            c: -self.c,
            terms: self.terms.iter().map(|(k, v)| (k.clone(), -v)).collect(),
        }
    }

    /// `self · k`.
    pub fn scale(&self, k: i64) -> LinearForm {
        LinearForm {
            a: self.a * k,
            c: self.c * k,
            terms: self.terms.iter().map(|(key, v)| (key.clone(), v * k)).collect(),
        }
        .normalized()
    }

    /// `self · r`, or `None` when the product mentions the counter
    /// non-linearly (counter × non-constant).
    pub fn mul(&self, r: &LinearForm) -> Option<LinearForm> {
        if self.is_const() {
            return Some(r.scale(self.c));
        }
        if r.is_const() {
            return Some(self.scale(r.c));
        }
        if self.a != 0 || r.a != 0 {
            return None; // counter times a non-constant: nonlinear
        }
        let mut terms: BTreeMap<Vec<Symbol>, i64> = BTreeMap::new();
        for (k1, v1) in &self.terms {
            for (k2, v2) in &r.terms {
                let mut key: Vec<Symbol> = k1.iter().chain(k2.iter()).copied().collect();
                key.sort();
                *terms.entry(key).or_insert(0) += v1 * v2;
            }
            if r.c != 0 {
                *terms.entry(k1.clone()).or_insert(0) += v1 * r.c;
            }
        }
        if self.c != 0 {
            for (k2, v2) in &r.terms {
                *terms.entry(k2.clone()).or_insert(0) += v2 * self.c;
            }
        }
        Some(LinearForm { a: 0, c: self.c * r.c, terms }.normalized())
    }
}

/// Affine form of `e` in `counter`, or `None` when nonlinear.
pub fn parse_linear(e: &Expr, counter: Symbol) -> Option<LinearForm> {
    match &e.kind {
        ExprKind::IntLit(k) => Some(LinearForm::constant(*k)),
        ExprKind::Var(n) if *n == counter => {
            Some(LinearForm { a: 1, c: 0, terms: BTreeMap::new() })
        }
        ExprKind::Var(n) => {
            let mut terms = BTreeMap::new();
            terms.insert(vec![*n], 1);
            Some(LinearForm { a: 0, c: 0, terms })
        }
        ExprKind::Unary(UnOp::Neg, x) => Some(parse_linear(x, counter)?.neg()),
        ExprKind::Binary(op @ (BinOp::Add | BinOp::Sub | BinOp::Mul), l, r) => {
            let lf = parse_linear(l, counter)?;
            let rf = parse_linear(r, counter)?;
            match op {
                BinOp::Add => Some(lf.add(&rf)),
                BinOp::Sub => Some(lf.add(&rf.neg())),
                _ => lf.mul(&rf),
            }
        }
        _ => None,
    }
}

/// What the dependence tests know about one loop's iteration space.
#[derive(Debug, Clone, Default)]
pub struct Bounds {
    /// Canonical counter increment (always positive).
    pub step: i64,
    /// `max − min` counter value, floored to a step multiple, when both
    /// bounds are integer constants (0 for a provably zero-trip loop).
    pub width: Option<i64>,
    /// `hi − lo` as a symbolic form — only for a *strict* (`<`) bound,
    /// where `|i − i′| < hi − lo` holds exactly.
    pub span: Option<LinearForm>,
    /// Concrete initial counter value, when `lo` is constant.
    pub lo: Option<i64>,
}

impl Bounds {
    /// Derive the iteration-space facts of one canonical loop.
    pub fn of(can: &CanonicalLoop) -> Bounds {
        let strict = !can.inclusive;
        let mut b = Bounds { step: can.step, width: None, span: None, lo: None };
        let lo_f = parse_linear(&can.lo, can.var);
        if let Some(f) = &lo_f {
            if f.is_const() {
                b.lo = Some(f.c);
            }
        }
        let hi_f = parse_linear(&can.hi, can.var);
        let (Some(lo_f), Some(hi_f)) = (lo_f, hi_f) else { return b };
        if lo_f.a != 0 || hi_f.a != 0 {
            return b;
        }
        let span = hi_f.add(&lo_f.neg());
        if span.terms.is_empty() {
            let w = span.c - if strict { 1 } else { 0 };
            b.width = Some(if w >= 0 { (w / can.step) * can.step } else { 0 });
        } else if strict {
            b.span = Some(span);
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cparse::parse;
    use crate::ir::loops;

    fn form(src_expr: &str, counter: &str) -> Option<LinearForm> {
        // wrap the subscript in a tiny program so the real parser builds it
        let src = format!(
            "float a[10]; void f(int i, int n, int b, int s) {{ a[{src_expr}] = 0.0; }}"
        );
        let p = parse(&src).expect("expr parses");
        let mut out = None;
        for f in &p.functions {
            for st in &f.body {
                st.walk(&mut |s| {
                    if let crate::cparse::ast::Stmt::Assign {
                        target: crate::cparse::ast::LValue::Index(_, idx),
                        ..
                    } = s
                    {
                        out = Some((**idx).clone());
                    }
                });
            }
        }
        parse_linear(&out.expect("found subscript"), Symbol::intern(counter))
    }

    #[test]
    fn constant_and_counter_forms() {
        let f = form("7", "i").unwrap();
        assert_eq!((f.a, f.c), (0, 7));
        assert!(f.terms.is_empty());
        let f = form("i", "i").unwrap();
        assert_eq!((f.a, f.c), (1, 0));
    }

    #[test]
    fn affine_combination() {
        // 2*i + n - 3
        let f = form("2 * i + n - 3", "i").unwrap();
        assert_eq!((f.a, f.c), (2, -3));
        assert_eq!(f.terms.get(&vec![Symbol::intern("n")]), Some(&1));
    }

    #[test]
    fn symbol_products_sort() {
        // b*s and s*b are one term
        let f1 = form("b * s", "i").unwrap();
        let f2 = form("s * b", "i").unwrap();
        assert_eq!(f1, f2);
        assert_eq!(f1.terms.len(), 1);
    }

    #[test]
    fn counter_times_symbol_is_nonlinear() {
        assert!(form("i * n", "i").is_none());
        assert!(form("n * i", "i").is_none());
        // counter times a constant stays linear
        assert_eq!(form("i * 4", "i").unwrap().a, 4);
    }

    #[test]
    fn cancellation_normalizes() {
        let f = form("n - n + i", "i").unwrap();
        assert!(f.terms.is_empty());
        assert_eq!((f.a, f.c), (1, 0));
    }

    fn bounds_of(src: &str) -> Bounds {
        let p = parse(src).expect("parses");
        let l = loops::extract(&p);
        Bounds::of(l[0].canonical.as_ref().expect("canonical"))
    }

    #[test]
    fn concrete_bounds_have_width_and_lo() {
        let b = bounds_of("void f() { for (int i = 2; i < 10; i++) { } }");
        assert_eq!(b.width, Some(7));
        assert_eq!(b.lo, Some(2));
        assert!(b.span.is_none());
    }

    #[test]
    fn width_floors_to_step_multiple() {
        let b = bounds_of("void f() { for (int i = 0; i <= 10; i += 3) { } }");
        assert_eq!(b.width, Some(9));
    }

    #[test]
    fn symbolic_strict_bound_keeps_span() {
        let b = bounds_of("void f(int n) { for (int i = 0; i < n; i++) { } }");
        assert!(b.width.is_none());
        let span = b.span.expect("span form");
        assert_eq!(span.terms.get(&vec![Symbol::intern("n")]), Some(&1));
        assert_eq!(b.lo, Some(0));
    }

    #[test]
    fn zero_trip_loop_width_is_zero() {
        let b = bounds_of("void f() { for (int i = 5; i < 3; i++) { } }");
        assert_eq!(b.width, Some(0));
    }
}
