//! Pairwise subscript dependence tests.
//!
//! Given two affine subscripts `f(i)` and `g(i′)` of the same array and
//! the loop's [`Bounds`], [`classify_pair`] decides whether the conflict
//! equation `f(i) = g(i′)` can hold for distinct iterations `i ≠ i′`.
//! The test hierarchy is classical: ZIV for counter-free pairs, strong
//! SIV for equal coefficients, a GCD filter and a Banerjee bounds check
//! for the MIV shapes our subscript grammar can produce, plus a
//! symbolic-span Banerjee variant for strict counted loops with
//! symbolic bounds.

use std::fmt;

use super::linear::{Bounds, LinearForm};

/// Which dependence test decided (or gave up on) a subscript pair.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum DepTest {
    /// Zero-index-variable: neither subscript mentions the counter.
    Ziv,
    /// Strong single-index-variable: equal counter coefficients,
    /// constant difference.
    SivStrong,
    /// Equal coefficients but a symbolic difference the span could not
    /// discharge.
    SivSymbolic,
    /// Symbolic difference proved out of range by the strict-bound span
    /// (`|i − i′| < hi − lo`).
    BanerjeeSymbolic,
    /// Differing coefficients, constant difference not divisible by
    /// their GCD.
    Gcd,
    /// Differing coefficients, difference outside the Banerjee value
    /// bounds of `a₁·i − a₂·i′`.
    Banerjee,
    /// Differing coefficients within Banerjee bounds: assumed carried.
    MivBanerjee,
    /// Differing coefficients with symbolic parts or symbolic loop
    /// bounds: no verdict.
    MivSymbolic,
}

impl DepTest {
    /// Stable kebab-case name used in diagnostics and counters.
    pub fn as_str(self) -> &'static str {
        match self {
            DepTest::Ziv => "ziv",
            DepTest::SivStrong => "siv-strong",
            DepTest::SivSymbolic => "siv-symbolic",
            DepTest::BanerjeeSymbolic => "banerjee-symbolic",
            DepTest::Gcd => "gcd",
            DepTest::Banerjee => "banerjee",
            DepTest::MivBanerjee => "miv-banerjee",
            DepTest::MivSymbolic => "miv-symbolic",
        }
    }
}

impl fmt::Display for DepTest {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Outcome of one subscript-pair test.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PairKind {
    /// The two subscripts can never touch the same element in distinct
    /// iterations.
    Independent,
    /// They coincide only within a single iteration (distance zero).
    SameIter,
    /// A loop-carried conflict exists (or must be assumed).
    Carried,
    /// The tests could not decide.
    Unknown,
}

fn gcd64(mut a: i64, mut b: i64) -> i64 {
    a = a.abs();
    b = b.abs();
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

/// Can `f(i) == g(i′)` hold for iterations `i ≠ i′` of the loop?
///
/// The dependence equation is `f.a·i − g.a·i′ = R` with
/// `R = (g − f)` restricted to its counter-free part.
pub fn classify_pair(f: &LinearForm, g: &LinearForm, bnd: &Bounds) -> (PairKind, DepTest) {
    let r_form = LinearForm { a: 0, c: g.c, terms: g.terms.clone() }
        .add(&LinearForm { a: 0, c: f.c, terms: f.terms.clone() }.neg());
    let (a1, a2) = (f.a, g.a);
    if a1 == a2 {
        let a = a1;
        if !r_form.terms.is_empty() {
            // Symbolic delta.  Banerjee with symbolic bounds: for a
            // strict counted loop, |i − i′| <= (hi−lo) − 1 < hi−lo, so
            // a delta of exactly ±a·(hi−lo) can never be matched.
            if a != 0 {
                if let Some(span) = &bnd.span {
                    let scaled = span.scale(a);
                    if r_form == scaled || r_form == scaled.neg() {
                        return (PairKind::Independent, DepTest::BanerjeeSymbolic);
                    }
                }
            }
            return (PairKind::Unknown, DepTest::SivSymbolic);
        }
        let r = r_form.c;
        if a == 0 {
            return if r == 0 {
                (PairKind::Carried, DepTest::Ziv)
            } else {
                (PairKind::Independent, DepTest::Ziv)
            };
        }
        if r % a != 0 {
            return (PairKind::Independent, DepTest::SivStrong);
        }
        let d = r / a; // i − i′ in counter units
        if d == 0 {
            return (PairKind::SameIter, DepTest::SivStrong);
        }
        if d % bnd.step != 0 {
            return (PairKind::Independent, DepTest::SivStrong);
        }
        if let Some(width) = bnd.width {
            if d.abs() > width {
                return (PairKind::Independent, DepTest::SivStrong);
            }
        }
        // symbolic bounds: assume the range covers |d|
        return (PairKind::Carried, DepTest::SivStrong);
    }
    // MIV-style: differing counter coefficients.
    if !r_form.terms.is_empty() {
        return (PairKind::Unknown, DepTest::MivSymbolic);
    }
    let r = r_form.c;
    let g_ = gcd64(a1, a2);
    if g_ != 0 && r % g_ != 0 {
        return (PairKind::Independent, DepTest::Gcd);
    }
    if let (Some(lo), Some(width)) = (bnd.lo, bnd.width) {
        // Banerjee value bounds of a1·i − a2·i′ with both counters
        // ranging over {lo, lo+width} (linear ⇒ extremes at endpoints).
        let pts = [lo, lo + width];
        let min1 = pts.iter().map(|v| a1 * v).min().unwrap();
        let max1 = pts.iter().map(|v| a1 * v).max().unwrap();
        let min2 = pts.iter().map(|v| a2 * v).min().unwrap();
        let max2 = pts.iter().map(|v| a2 * v).max().unwrap();
        if r < min1 - max2 || r > max1 - min2 {
            return (PairKind::Independent, DepTest::Banerjee);
        }
        return (PairKind::Carried, DepTest::MivBanerjee);
    }
    (PairKind::Unknown, DepTest::MivSymbolic)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::intern::Symbol;
    use std::collections::BTreeMap;

    fn af(a: i64, c: i64) -> LinearForm {
        LinearForm { a, c, terms: BTreeMap::new() }
    }

    fn sym(name: &str, coeff: i64) -> LinearForm {
        let mut terms = BTreeMap::new();
        terms.insert(vec![Symbol::intern(name)], coeff);
        LinearForm { a: 0, c: 0, terms }
    }

    fn concrete(step: i64, lo: i64, width: i64) -> Bounds {
        Bounds { step, width: Some(width), span: None, lo: Some(lo) }
    }

    #[test]
    fn ziv_distinct_constants_independent() {
        let b = concrete(1, 0, 9);
        assert_eq!(
            classify_pair(&af(0, 3), &af(0, 7), &b),
            (PairKind::Independent, DepTest::Ziv)
        );
        assert_eq!(
            classify_pair(&af(0, 3), &af(0, 3), &b),
            (PairKind::Carried, DepTest::Ziv)
        );
    }

    #[test]
    fn strong_siv_distance() {
        let b = concrete(1, 0, 9);
        // a[i] vs a[i] — same iteration only
        assert_eq!(
            classify_pair(&af(1, 0), &af(1, 0), &b),
            (PairKind::SameIter, DepTest::SivStrong)
        );
        // a[i] vs a[i-1] — carried at distance 1
        assert_eq!(
            classify_pair(&af(1, 0), &af(1, -1), &b),
            (PairKind::Carried, DepTest::SivStrong)
        );
        // 2i vs 2i+1 — parity never matches
        assert_eq!(
            classify_pair(&af(2, 0), &af(2, 1), &b),
            (PairKind::Independent, DepTest::SivStrong)
        );
    }

    #[test]
    fn strong_siv_width_prunes_far_distances() {
        let b = concrete(1, 0, 4);
        // distance 7 over a width-4 space: unreachable
        assert_eq!(
            classify_pair(&af(1, 0), &af(1, -7), &b),
            (PairKind::Independent, DepTest::SivStrong)
        );
    }

    #[test]
    fn strong_siv_step_filters_off_grid() {
        let b = concrete(4, 0, 16);
        // distance 2 with step 4: counters differ by multiples of 4
        assert_eq!(
            classify_pair(&af(1, 0), &af(1, -2), &b),
            (PairKind::Independent, DepTest::SivStrong)
        );
    }

    #[test]
    fn gcd_filter() {
        let b = Bounds { step: 1, width: None, span: None, lo: None };
        // 2i vs 4i'+1: gcd 2 does not divide 1
        assert_eq!(
            classify_pair(&af(2, 0), &af(4, 1), &b),
            (PairKind::Independent, DepTest::Gcd)
        );
    }

    #[test]
    fn banerjee_bounds() {
        let b = concrete(1, 0, 4);
        // i vs 2i'+100 over [0,4]: value sets [0,4] vs [100,108] disjoint
        assert_eq!(
            classify_pair(&af(1, 0), &af(2, 100), &b),
            (PairKind::Independent, DepTest::Banerjee)
        );
        // i vs 2i' over [0,4]: overlap, assumed carried
        assert_eq!(
            classify_pair(&af(1, 0), &af(2, 0), &b),
            (PairKind::Carried, DepTest::MivBanerjee)
        );
    }

    #[test]
    fn banerjee_symbolic_span_discharges_exact_offset() {
        // loop i in [base, base+half) writing x[i] and x[i+half]:
        // delta == span ⇒ never reachable for i ≠ i′ (and the engine
        // separately skips the structurally-equal same-iteration pair)
        let b = Bounds { step: 1, width: None, span: Some(sym("half", 1)), lo: None };
        let f = af(1, 0);
        let g = af(1, 0).add(&sym("half", 1));
        assert_eq!(
            classify_pair(&f, &g, &b),
            (PairKind::Independent, DepTest::BanerjeeSymbolic)
        );
        assert_eq!(
            classify_pair(&g, &f, &b),
            (PairKind::Independent, DepTest::BanerjeeSymbolic)
        );
        // a different symbolic offset stays undecided
        let h = af(1, 0).add(&sym("quarter", 1));
        assert_eq!(
            classify_pair(&f, &h, &b),
            (PairKind::Unknown, DepTest::SivSymbolic)
        );
    }

    #[test]
    fn miv_with_symbols_is_unknown() {
        let b = concrete(1, 0, 9);
        let g = af(2, 0).add(&sym("n", 1));
        assert_eq!(
            classify_pair(&af(1, 0), &g, &b),
            (PairKind::Unknown, DepTest::MivSymbolic)
        );
    }
}
