//! Loop-carried dependence engine.
//!
//! This module decides, per canonical loop, whether iterations may run
//! in parallel — the verdict the offload pipeline previously derived
//! from a set of ad-hoc syntactic gates in [`crate::ir::deps`].  The
//! engine keeps the legacy gate *order* (so diagnostics stay stable)
//! but proves each array verdict with classical subscript dependence
//! tests over affine forms ([`linear`]), pairwise classification
//! ([`pairs`]), and records every dependence fact, optimistic
//! assumption, and fired test for the `flopt explain` diagnostics
//! ([`explain`]).
//!
//! The contract with the rest of the pipeline is
//! [`LoopDeps::to_dep_analysis`]: verdicts collapse onto the legacy
//! `offloadable` / `reject_reason` pair consumed by the Analyze and
//! IntensityNarrow stages, and are validated against a dynamic
//! dependence oracle (`interp::oracle`) by the generative suite's
//! seventh invariant.

pub mod engine;
pub mod explain;
pub mod linear;
pub mod pairs;

use std::collections::BTreeMap;
use std::fmt;

use crate::ir::Reduction;
use crate::util::intern::Symbol;

pub use engine::analyze_loop;
pub use explain::{explain_program, ExplainArtifact, ExplainReport, LoopExplain};
pub use linear::{parse_linear, Bounds, LinearForm};
pub use pairs::{classify_pair, DepTest, PairKind};

/// Why a loop was rejected (or left undecided) for offload.
///
/// One variant per diagnostic the pipeline can emit; the [`fmt::Display`]
/// strings are load-bearing — they appear in golden analyze reports,
/// regression pins, and `flopt explain` output.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum RejectReason {
    /// The loop has no canonical counted header (`for (i = lo; i < hi; i += s)`).
    NoCanonicalHeader,
    /// A variable in the loop bound is written inside the body.
    BoundWritten,
    /// The body calls a function that is not a known builtin.
    NonBuiltinCall,
    /// The body contains a `return`.
    BodyReturn,
    /// An array is written at an index that never mentions the counter.
    InvariantWriteIndex,
    /// An array is written at an index loaded from another array.
    DataDependentWriteIndex,
    /// A write/read subscript pair may touch the same element across
    /// iterations (flow or anti dependence).
    ReadWriteMismatch,
    /// A scalar is both read and written without forming a reduction.
    CarriedScalar,
    /// A reduction variable's running value is consumed inside the loop.
    ReductionConsumed,
    /// Two write subscripts may store to the same element across
    /// iterations (output dependence).
    WwOverlap,
}

impl RejectReason {
    /// The exact legacy diagnostic string for this reason.
    pub fn as_str(self) -> &'static str {
        match self {
            RejectReason::NoCanonicalHeader => "no canonical counted header",
            RejectReason::BoundWritten => "loop bound written inside body",
            RejectReason::NonBuiltinCall => "calls non-builtin function",
            RejectReason::BodyReturn => "body contains return",
            RejectReason::InvariantWriteIndex => "array written at loop-invariant index",
            RejectReason::DataDependentWriteIndex => "array written at data-dependent index",
            RejectReason::ReadWriteMismatch => {
                "array read/write index mismatch (possible cross-iteration dependence)"
            }
            RejectReason::CarriedScalar => "loop-carried scalar dependence (not a reduction)",
            RejectReason::ReductionConsumed => "reduction value consumed inside the loop",
            RejectReason::WwOverlap => "array write/write overlap across iterations",
        }
    }
}

impl fmt::Display for RejectReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// The engine's verdict for one loop.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LoopVerdict {
    /// Iterations are independent; the loop may be offloaded.
    Parallel,
    /// Iterations are independent except for the named reduction
    /// variables; offloadable with reduction support.
    Reduction(Vec<Symbol>),
    /// A proven dependence (or hard structural property) serializes the
    /// loop.
    Sequential(RejectReason),
    /// The engine could not decide; treated as not offloadable.
    Unknown(RejectReason),
}

impl LoopVerdict {
    /// Lowercase tag used in diagnostics and JSON.
    pub fn tag(&self) -> &'static str {
        match self {
            LoopVerdict::Parallel => "parallel",
            LoopVerdict::Reduction(_) => "reduction",
            LoopVerdict::Sequential(_) => "sequential",
            LoopVerdict::Unknown(_) => "unknown",
        }
    }

    /// May the loop be offloaded?
    pub fn offloadable(&self) -> bool {
        matches!(self, LoopVerdict::Parallel | LoopVerdict::Reduction(_))
    }

    /// The reject reason, for non-offloadable verdicts.
    pub fn reject_reason(&self) -> Option<RejectReason> {
        match self {
            LoopVerdict::Sequential(r) | LoopVerdict::Unknown(r) => Some(*r),
            _ => None,
        }
    }
}

/// Dependence class of a recorded fact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DepClass {
    /// Write/read conflict (flow or anti — the engine does not orient
    /// the pair, it only needs existence).
    FlowAnti,
    /// Write/write conflict.
    Output,
}

impl DepClass {
    /// Stable tag for diagnostics.
    pub fn as_str(self) -> &'static str {
        match self {
            DepClass::FlowAnti => "flow/anti",
            DepClass::Output => "output",
        }
    }
}

/// One dependence the engine proved or had to assume.
#[derive(Debug, Clone)]
pub struct DepFact {
    /// Flow/anti or output.
    pub class: DepClass,
    /// The array involved.
    pub array: Symbol,
    /// Source subscript expression (a write).
    pub source: crate::cparse::ast::Expr,
    /// Sink subscript expression (read for flow/anti, write for output).
    pub sink: crate::cparse::ast::Expr,
    /// The test that fired.
    pub test: DepTest,
}

/// Kind of optimistic assumption recorded as a [`Note`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NoteKind {
    /// A write/read pair was proven independent by a subscript test
    /// (strictly better than the legacy structural-equality gate).
    ReadProvedIndependent,
    /// A non-affine write subscript was assumed injective across
    /// iterations (legacy behaviour, kept for parity).
    AssumedInjective,
    /// Two write subscripts with a non-affine member were assumed
    /// disjoint (legacy behaviour, kept for parity).
    AssumedDisjoint,
}

impl NoteKind {
    /// Stable kebab-case tag for diagnostics.
    pub fn as_str(self) -> &'static str {
        match self {
            NoteKind::ReadProvedIndependent => "read-proved-independent",
            NoteKind::AssumedInjective => "assumed-injective",
            NoteKind::AssumedDisjoint => "assumed-disjoint",
        }
    }
}

/// An optimistic assumption or extra proof the engine wants surfaced in
/// diagnostics without affecting the verdict.
#[derive(Debug, Clone)]
pub struct Note {
    /// What was assumed or proved.
    pub kind: NoteKind,
    /// The array involved.
    pub array: Symbol,
    /// The subscript expressions involved (one or two).
    pub subscripts: Vec<crate::cparse::ast::Expr>,
}

/// Full dependence analysis of one loop.
#[derive(Debug, Clone)]
pub struct LoopDeps {
    /// The verdict.
    pub verdict: LoopVerdict,
    /// Recognized reductions (verdict [`LoopVerdict::Reduction`] lists
    /// the same variables).
    pub reductions: Vec<Reduction>,
    /// Dependences proved or assumed (the first fatal one ends the
    /// analysis, so rejection verdicts carry exactly the fact that
    /// fired).
    pub deps: Vec<DepFact>,
    /// Optimistic-tier notes.
    pub notes: Vec<Note>,
    /// How often each subscript test fired.
    pub tests: BTreeMap<DepTest, u32>,
}

impl Default for LoopDeps {
    fn default() -> LoopDeps {
        LoopDeps {
            verdict: LoopVerdict::Parallel,
            reductions: Vec::new(),
            deps: Vec::new(),
            notes: Vec::new(),
            tests: BTreeMap::new(),
        }
    }
}

impl LoopDeps {
    /// May the loop be offloaded?
    pub fn offloadable(&self) -> bool {
        self.verdict.offloadable()
    }

    /// The reject reason, for non-offloadable verdicts.
    pub fn reject_reason(&self) -> Option<RejectReason> {
        self.verdict.reject_reason()
    }

    /// Collapse onto the legacy pipeline contract.
    pub fn to_dep_analysis(&self) -> crate::ir::DepAnalysis {
        crate::ir::DepAnalysis {
            offloadable: self.offloadable(),
            reject_reason: self.reject_reason(),
            reductions: self.reductions.clone(),
        }
    }
}
