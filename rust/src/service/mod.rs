//! Batched offload service: many offload requests, one compile farm.
//!
//! The production story the ROADMAP asks for: offload requests
//! (app × target × config) arrive N at a time; re-running one analysis,
//! pre-compile, or ≈3-hour full compile per request would re-pay exactly
//! the cost the paper's method exists to avoid.  The scheduler here:
//!
//! 1. **dedupes** identical requests (and requests already satisfied by
//!    the content-addressed cache, [`crate::cache`]) down to unique
//!    *units* of work;
//! 2. **analyzes each app once** (Steps 1–2 are backend-independent);
//! 3. runs the unique units **concurrently** on [`crate::util::pool`],
//!    each on a private simulated clock with a private artifact store
//!    seeded deterministically from the shared cache — so a unit's
//!    result and accounting are a pure function of its inputs, never of
//!    worker interleaving;
//! 4. **merges** results in submission order, replaying each cold
//!    unit's simulated events onto the shared batch clock — makespan
//!    accounting over one shared compile farm, byte-identical output for
//!    any worker count.
//!
//! Exposed as `flopt batch`; the mixed-destination search
//! ([`crate::coordinator::mixed`]) and `benches/service_throughput.rs`
//! are built on it.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use crate::apps::App;
use crate::backend::{OffloadBackend, SearchMethod, Target};
use crate::cache::{self, CacheKey, CacheStats, CacheStore};
use crate::config::SearchConfig;
use crate::coordinator::mixed::{ga_destination_search, DestinationSearch};
use crate::coordinator::pipeline::{offload_search, AppAnalysis, SearchTrace};
use crate::coordinator::verify_env::VerifyEnv;
use crate::cpu::CpuModel;
use crate::funcblock::BlockMode;
use crate::metrics::{Event, SimClock};
use crate::util::pool::Pool;

/// One offload request: search `app` for `target` under `cfg`.
#[derive(Debug, Clone)]
pub struct BatchRequest {
    /// The application to search.
    pub app: &'static App,
    /// The destination to compile for (must be `fpga` or `gpu`; `mixed`
    /// is a *composition* of requests, not a request).
    pub target: Target,
    /// Narrowing/search parameters.
    pub cfg: SearchConfig,
    /// Run the sample workload at CI test scale?
    pub test_scale: bool,
}

impl BatchRequest {
    /// A request with the paper-default [`SearchConfig`].
    pub fn new(app: &'static App, target: Target, test_scale: bool) -> Self {
        Self { app, target, cfg: SearchConfig::default(), test_scale }
    }
}

/// How the service satisfied one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheDisposition {
    /// The search actually ran (and its hours were charged).
    Cold,
    /// Served from the artifact cache — zero simulated hours burned.
    Warm,
    /// Duplicate of a unit already run in this batch — zero extra hours.
    Deduped,
}

impl CacheDisposition {
    /// Report label.
    pub fn as_str(self) -> &'static str {
        match self {
            CacheDisposition::Cold => "cold",
            CacheDisposition::Warm => "warm",
            CacheDisposition::Deduped => "dedup",
        }
    }
}

/// One request's result row.
#[derive(Debug, Clone)]
pub struct BatchItem {
    /// The search outcome for this request.
    pub outcome: DestinationSearch,
    /// How the service satisfied it.
    pub disposition: CacheDisposition,
    /// Shared-clock snapshot (total simulated hours) after this item was
    /// accounted, in submission order.
    pub sim_hours_after: f64,
}

/// The deterministic batch result.
#[derive(Debug, Clone)]
pub struct BatchReport {
    /// Per-request rows, in submission order.
    pub items: Vec<BatchItem>,
    /// Unique units actually executed this run.
    pub unique_cold: usize,
    /// Requests served warm from the cache.
    pub warm_hits: usize,
    /// Requests deduplicated against an identical in-batch request.
    pub deduped: usize,
    /// Simulated makespan this batch added to the shared clock (hours).
    pub sim_hours: f64,
    /// Compile-lane hours this batch burned.
    pub compile_hours: f64,
    /// Compile-lane hours *not* burned thanks to cache hits + dedupe.
    pub saved_compile_hours: f64,
    /// Shared artifact-cache counters after the batch completed.
    pub cache: CacheStats,
}

impl BatchReport {
    /// Render the batch table (identical for any worker count).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "=== batch offload service: {} request(s) ===\n",
            self.items.len()
        ));
        out.push_str(&format!(
            "{:<12} {:<6} {:<16} {:>9} {:>9} {:>11} {:>7}\n",
            "app", "dest", "method", "speedup", "patterns", "compile-h", "cache"
        ));
        for it in &self.items {
            let o = &it.outcome;
            out.push_str(&format!(
                "{:<12} {:<6} {:<16} {:>8.2}x {:>9} {:>11.1} {:>7}\n",
                o.app_name,
                o.destination,
                o.method,
                o.speedup,
                o.patterns_measured,
                o.compile_hours,
                it.disposition.as_str()
            ));
        }
        out.push_str(&format!(
            "unique searches run: {} ({} warm from cache, {} deduped in-batch)\n",
            self.unique_cold, self.warm_hits, self.deduped
        ));
        out.push_str(&format!(
            "compile-lane hours burned: {:.1} (saved {:.1} via cache + dedupe)\n",
            self.compile_hours, self.saved_compile_hours
        ));
        out.push_str(&format!(
            "shared-clock makespan: {:.1} h simulated\n",
            self.sim_hours
        ));
        out.push_str(&format!(
            "cache: {} mem + {} disk hits · {} misses · {} evictions · \
             {} disk read errors · {} corrupt recomputes\n",
            self.cache.mem_hits,
            self.cache.disk_hits,
            self.cache.misses,
            self.cache.evictions(),
            self.cache.disk_read_errors,
            self.cache.corrupt_recomputes()
        ));
        out
    }
}

/// A unique unit of work after request deduplication.
struct Unit {
    app: &'static App,
    backend: &'static dyn OffloadBackend,
    cfg: SearchConfig,
    test_scale: bool,
    key: CacheKey,
}

/// Post-execution state of a unit (cold payload boxed: it carries the
/// full trace and event log).
enum UnitState {
    Warm(DestinationSearch),
    Cold(Box<ColdUnit>),
}

/// What a cold unit produced on its private clock.
struct ColdUnit {
    outcome: DestinationSearch,
    events: Vec<Event>,
    trace: Option<SearchTrace>,
    /// The unit clock's span/metrics recorder, folded into the shared
    /// recorder (in submission order) when the unit is merged.
    obs: Arc<crate::obs::Recorder>,
}

/// The batch offload scheduler (see module docs).
pub struct BatchService {
    workers: usize,
    cache: Arc<CacheStore>,
    clock: Arc<SimClock>,
    cpu: Arc<CpuModel>,
}

impl BatchService {
    /// A service with `workers` pool workers and a compile farm of
    /// `lanes` lanes on a fresh shared clock and a fresh in-memory
    /// artifact cache.
    pub fn new(workers: usize, lanes: usize, cpu: &CpuModel) -> Self {
        Self {
            workers: workers.max(1),
            cache: CacheStore::fresh(),
            clock: Arc::new(SimClock::new(lanes.max(1))),
            cpu: Arc::new(cpu.clone()),
        }
    }

    /// Replace the artifact cache (e.g. an on-disk `--cache-dir` store).
    ///
    /// Request deduplication and analyze-once are the service's core
    /// contract and require a live store, so a disabled store
    /// (`--no-cache`) is upgraded to a fresh in-memory one: batch runs
    /// then reuse nothing from previous runs and persist nothing, but
    /// still dedupe within the batch (documented in the README).
    pub fn with_cache(mut self, cache: Arc<CacheStore>) -> Self {
        self.cache = if cache.is_enabled() { cache } else { CacheStore::fresh() };
        self
    }

    /// The shared batch clock (mixed-mode reports snapshot it).
    pub fn clock(&self) -> &Arc<SimClock> {
        &self.clock
    }

    /// The shared artifact cache.
    pub fn cache(&self) -> &Arc<CacheStore> {
        &self.cache
    }

    /// The CPU model requests are measured against (the fleet layer
    /// reuses it for trace-level fallback searches on the shared clock).
    pub fn cpu(&self) -> &CpuModel {
        &self.cpu
    }

    /// Run a batch: results come back in submission order and are
    /// byte-identical for any worker count.
    pub fn run(&self, requests: &[BatchRequest]) -> crate::Result<BatchReport> {
        let span = self.clock.span_meter();

        // ---- resolve + dedupe into unique units (submission order) ----
        let mut units: Vec<Unit> = Vec::new();
        let mut unit_of: Vec<usize> = Vec::with_capacity(requests.len());
        let mut index_of: HashMap<CacheKey, usize> = HashMap::new();
        for r in requests {
            let backend = r
                .target
                .destination()
                .and_then(|d| d.backend())
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "batch requests must name a concrete destination (fpga or gpu); \
                         `mixed` is a composition of requests"
                    )
                })?;
            let key = cache::destination_key(r.app, r.test_scale, backend, &r.cfg);
            let idx = *index_of.entry(key).or_insert_with(|| {
                units.push(Unit {
                    app: r.app,
                    backend,
                    cfg: r.cfg.clone(),
                    test_scale: r.test_scale,
                    key,
                });
                units.len() - 1
            });
            unit_of.push(idx);
        }

        // ---- resolve warm units from the shared cache (sequential) ----
        let mut states: Vec<Option<UnitState>> = units
            .iter()
            .map(|u| {
                if let Some(d) = self.cache.get_destination(u.key) {
                    crate::coordinator::pipeline::cache_hit(&self.clock, "cache.hit.destination");
                    return Some(UnitState::Warm(d));
                }
                // a narrowed-flow unit whose full trace is already
                // cached (e.g. written by `flopt offload --cache-dir`)
                // needs no execution: synthesize its outcome from the
                // trace and serve it warm
                if u.backend.search_method() == SearchMethod::NarrowedTwoRound {
                    let tkey = cache::trace_key(u.app, u.test_scale, u.backend, &u.cfg);
                    if let Some(t) = self.cache.get_trace(tkey) {
                        crate::coordinator::pipeline::cache_hit(&self.clock, "cache.hit.trace");
                        let d = destination_from_trace(&t);
                        self.cache.put_destination(u.key, &d);
                        return Some(UnitState::Warm(d));
                    }
                }
                None
            })
            .collect();

        // ---- Steps 1-2 once per (app, scale) among cold units ----------
        // `charged[akey]` records whether this batch actually computed
        // the analysis (and must therefore account its simulated time).
        let mut analyze_specs: Vec<(CacheKey, &'static App, bool)> = Vec::new();
        let mut seen_apps: HashSet<CacheKey> = HashSet::new();
        for (u, state) in units.iter().zip(&states) {
            if state.is_some() {
                continue; // warm: no work, no analysis needed
            }
            let akey = cache::analyze_key(u.app, u.test_scale);
            if seen_apps.insert(akey) {
                analyze_specs.push((akey, u.app, u.test_scale));
            }
        }
        let pool = Pool::with_obs(self.workers, Arc::clone(self.clock.obs()));
        let mut analyses: HashMap<CacheKey, (Arc<AppAnalysis>, bool)> = HashMap::new();
        {
            // split warm-vs-compute *before* the parallel phase so the
            // charged set is independent of worker timing
            let mut to_compute: Vec<(CacheKey, &'static App, bool)> = Vec::new();
            for (akey, app, scale) in analyze_specs {
                match self.cache.get_analysis(akey) {
                    Some(a) => {
                        analyses.insert(akey, (a, false));
                    }
                    None => to_compute.push((akey, app, scale)),
                }
            }
            let computed = pool.map(to_compute, |(akey, app, scale)| {
                crate::coordinator::pipeline::analyze_app(app, scale)
                    .map(|a| (akey, Arc::new(a)))
                    .map_err(|e| format!("analyzing `{}`: {e}", app.name))
            });
            for r in computed {
                let (akey, a) = r.map_err(|e| anyhow::anyhow!("{e}"))?;
                self.cache.put_analysis(akey, Arc::clone(&a));
                analyses.insert(akey, (a, true));
            }
        }

        // ---- execute unique cold units concurrently --------------------
        // Each unit gets a private clock and a private store seeded (from
        // the shared cache, sequentially, up front) with its analysis and
        // any warm trace — execution is a pure function of the unit.
        let mut cold_specs: Vec<UnitSpec> = Vec::new();
        let mut publish: Vec<(Arc<CacheStore>, CacheKey, CacheKey, Option<CacheKey>)> =
            Vec::new();
        for (idx, (u, state)) in units.iter().zip(&states).enumerate() {
            if state.is_some() {
                continue;
            }
            let akey = cache::analyze_key(u.app, u.test_scale);
            let analysis = Arc::clone(&analyses[&akey].0);
            let store = CacheStore::fresh();
            store.put_analysis(akey, Arc::clone(&analysis));
            if u.backend.search_method() == SearchMethod::NarrowedTwoRound {
                // share stage artifacts with the unit (seeded up front,
                // so the unit stays a pure function of its spec) and
                // remember the keys so freshly computed artifacts can
                // publish back to the shared cache after the merge
                let pre_key = cache::precompile_key(u.app, &analysis, u.backend, &u.cfg);
                let meas_key = cache::measure_key(u.app, &analysis, u.backend, &u.cfg);
                if let Some(p) = self.cache.get_precompile(pre_key) {
                    store.put_precompile(pre_key, &p);
                }
                if let Some(m) = self.cache.get_measure(meas_key) {
                    store.put_measure(meas_key, &m);
                }
                let blocks_key = if u.cfg.block_mode != BlockMode::Off {
                    let k = cache::blocks_key(u.app, &analysis, u.backend, &u.cfg);
                    if let Some(b) = self.cache.get_blocks(k) {
                        store.put_blocks(k, &b);
                    }
                    Some(k)
                } else {
                    None
                };
                publish.push((Arc::clone(&store), pre_key, meas_key, blocks_key));
            }
            cold_specs.push(UnitSpec {
                idx,
                app: u.app,
                backend: u.backend,
                cfg: u.cfg.clone(),
                test_scale: u.test_scale,
                analysis,
                store,
            });
        }
        let cpu = Arc::clone(&self.cpu);
        let executed = pool.map(cold_specs, move |spec| {
            let idx = spec.idx;
            execute_unit(spec, &cpu).map(|r| (idx, r)).map_err(|e| format!("{e}"))
        });
        for r in executed {
            let (idx, (outcome, events, trace, obs)) = r.map_err(|e| anyhow::anyhow!("{e}"))?;
            states[idx] =
                Some(UnitState::Cold(Box::new(ColdUnit { outcome, events, trace, obs })));
        }

        // ---- deterministic merge in submission order -------------------
        let mut items: Vec<BatchItem> = Vec::with_capacity(requests.len());
        let mut replayed: HashSet<usize> = HashSet::new();
        let mut analysis_charged: HashSet<CacheKey> = HashSet::new();
        let (mut unique_cold, mut warm_hits, mut deduped) = (0usize, 0usize, 0usize);
        let mut saved_lane_s = 0.0f64;
        for &idx in &unit_of {
            let u = &units[idx];
            let state = states[idx].as_ref().expect("every unit resolved");
            let (outcome, disposition) = match state {
                UnitState::Warm(o) => {
                    warm_hits += 1;
                    saved_lane_s += o.compile_hours * 3600.0;
                    (o.clone(), CacheDisposition::Warm)
                }
                UnitState::Cold(cold) => {
                    let ColdUnit { outcome, events, trace, obs } = cold.as_ref();
                    if replayed.insert(idx) {
                        // first occurrence: account the unit on the
                        // shared clock (analysis once per app, only if
                        // this batch actually computed it)
                        let akey = cache::analyze_key(u.app, u.test_scale);
                        if let Some((analysis, computed)) = analyses.get(&akey) {
                            if *computed && analysis_charged.insert(akey) {
                                crate::coordinator::pipeline::charge_analysis(
                                    &self.clock,
                                    &self.cpu,
                                    analysis,
                                );
                            }
                        }
                        self.clock.replay(events);
                        // fold the unit's spans/metrics into the shared
                        // recorder, re-tracked to `1 + unit index` — same
                        // submission order as the replay above, so the
                        // merged span log is pool-size independent
                        self.clock.obs().merge_from(obs, idx as u32 + 1);
                        // publish the unit's artifacts to the shared cache
                        self.cache.put_destination(u.key, outcome);
                        if let Some(t) = trace {
                            let tkey =
                                cache::trace_key(u.app, u.test_scale, u.backend, &u.cfg);
                            self.cache.put_trace(tkey, t);
                        }
                        unique_cold += 1;
                        (outcome.clone(), CacheDisposition::Cold)
                    } else {
                        deduped += 1;
                        saved_lane_s += outcome.compile_hours * 3600.0;
                        (outcome.clone(), CacheDisposition::Deduped)
                    }
                }
            };
            items.push(BatchItem {
                outcome,
                disposition,
                sim_hours_after: self.clock.total_hours(),
            });
        }

        // ---- publish freshly computed stage artifacts ------------------
        // (deterministic: unit order; idempotent for seeded entries)
        for (store, pre_key, meas_key, blocks_key) in publish {
            if let Some(p) = store.get_precompile(pre_key) {
                self.cache.put_precompile(pre_key, &p);
            }
            if let Some(m) = store.get_measure(meas_key) {
                self.cache.put_measure(meas_key, &m);
            }
            if let Some(bkey) = blocks_key {
                if let Some(b) = store.get_blocks(bkey) {
                    self.cache.put_blocks(bkey, &b);
                }
            }
        }

        let obs = self.clock.obs();
        obs.count("batch.requests", requests.len() as u64);
        obs.count("batch.cold_units", unique_cold as u64);
        obs.count("batch.warm_hits", warm_hits as u64);
        obs.count("batch.deduped", deduped as u64);

        Ok(BatchReport {
            items,
            unique_cold,
            warm_hits,
            deduped,
            sim_hours: span.total_hours(),
            compile_hours: span.lane_hours(),
            saved_compile_hours: saved_lane_s / 3600.0,
            cache: self.cache.stats(),
        })
    }
}

/// Build a request-level outcome from a cached (or freshly computed)
/// narrowed-flow trace: the trace's canonical times make this a pure
/// function of the trace.  The carried solution is the trace's overall
/// winner — a block placement when one beat every loop pattern.
fn destination_from_trace(t: &SearchTrace) -> DestinationSearch {
    DestinationSearch {
        app_name: t.app_name.clone(),
        destination: t.destination,
        method: "narrowed-2round",
        speedup: t.speedup(),
        best: t.solution_measurement(),
        patterns_measured: t.patterns_measured(),
        compile_hours: t.compile_hours,
        cpu_time_s: t.cpu_time_s,
    }
}

/// Everything one cold unit needs, assembled deterministically before
/// the parallel phase.
struct UnitSpec {
    idx: usize,
    app: &'static App,
    backend: &'static dyn OffloadBackend,
    cfg: SearchConfig,
    test_scale: bool,
    analysis: Arc<AppAnalysis>,
    store: Arc<CacheStore>,
}

/// Run one unit on a private clock + private seeded store.  Returns the
/// outcome, the private clock's event log (for shared-clock replay), and
/// the full trace when the backend ran the narrowed flow.
fn execute_unit(
    spec: UnitSpec,
    cpu: &CpuModel,
) -> crate::Result<(
    DestinationSearch,
    Vec<Event>,
    Option<SearchTrace>,
    Arc<crate::obs::Recorder>,
)> {
    let clock = Arc::new(SimClock::new(spec.cfg.compile_parallelism.max(1)));
    let env = VerifyEnv::with_clock(spec.backend, cpu, spec.cfg.clone(), Arc::clone(&clock))
        .with_cache(Arc::clone(&spec.store));
    let (outcome, trace) = match spec.backend.search_method() {
        SearchMethod::NarrowedTwoRound => {
            let t = offload_search(spec.app, &env, spec.test_scale)?;
            // canonical trace times, not the meter: warm stage artifacts
            // must not make the stored outcome history-dependent
            let outcome = destination_from_trace(&t);
            (outcome, Some(t))
        }
        SearchMethod::MeasurementGa => {
            // shared GA + block co-search flow (meters the same clock)
            let outcome = ga_destination_search(&spec.analysis, &env, &spec.cfg);
            (outcome, None)
        }
    };
    let obs = Arc::clone(clock.obs());
    Ok((outcome, clock.events(), trace, obs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::cpu::XEON_3104;

    fn all_requests(test_scale: bool) -> Vec<BatchRequest> {
        let mut reqs = Vec::new();
        for app in apps::all() {
            for target in [Target::Fpga, Target::Gpu] {
                reqs.push(BatchRequest::new(app, target, test_scale));
            }
        }
        reqs
    }

    #[test]
    fn rejects_mixed_requests() {
        let svc = BatchService::new(2, 1, &XEON_3104);
        let req = BatchRequest::new(&apps::TDFIR, Target::Mixed, true);
        assert!(svc.run(&[req]).is_err());
    }

    #[test]
    fn duplicate_requests_are_deduped() {
        let svc = BatchService::new(4, 1, &XEON_3104);
        let req = BatchRequest::new(&apps::MATMUL, Target::Fpga, true);
        let report = svc.run(&[req.clone(), req.clone(), req]).unwrap();
        assert_eq!(report.items.len(), 3);
        assert_eq!(report.unique_cold, 1);
        assert_eq!(report.deduped, 2);
        assert_eq!(report.items[0].disposition, CacheDisposition::Cold);
        assert_eq!(report.items[1].disposition, CacheDisposition::Deduped);
        assert_eq!(report.items[2].disposition, CacheDisposition::Deduped);
        // all three rows carry the same outcome
        let s0 = report.items[0].outcome.speedup;
        assert!(report.items.iter().all(|it| it.outcome.speedup == s0));
        assert!(report.saved_compile_hours > 0.0, "dedupe must save hours");
    }

    #[test]
    fn second_batch_is_fully_warm_and_burns_nothing() {
        let svc = BatchService::new(4, 1, &XEON_3104);
        let first = svc.run(&all_requests(true)).unwrap();
        assert_eq!(first.warm_hits, 0);
        assert!(first.compile_hours > 0.0);
        let second = svc.run(&all_requests(true)).unwrap();
        assert_eq!(second.warm_hits, second.items.len());
        assert_eq!(second.unique_cold, 0);
        assert_eq!(second.compile_hours, 0.0, "warm batch burns zero lane hours");
        assert_eq!(second.sim_hours, 0.0, "warm batch adds zero makespan");
        assert!(second.saved_compile_hours > 0.0);
        // outcomes identical to the cold run
        for (a, b) in first.items.iter().zip(&second.items) {
            assert_eq!(a.outcome.speedup, b.outcome.speedup);
            assert_eq!(a.outcome.patterns_measured, b.outcome.patterns_measured);
            assert_eq!(a.outcome.compile_hours, b.outcome.compile_hours);
        }
    }
}
