//! Search configuration and testbed presets (paper §5.1, Fig 3).

use std::fmt;

use crate::funcblock::BlockMode;

/// The paper's narrowing / search parameters (§5.1.2 evaluation conditions).
#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// Step-2 cut: keep top-`a` loops by arithmetic intensity (paper: 5).
    pub a_intensity: usize,
    /// Loop-unroll factor applied when generating OpenCL (paper: 1 —
    /// "検証では OpenCL での FPGA オフロードした効果だけ確認する").
    pub b_unroll: usize,
    /// Step-3 cut: keep top-`c` loops by resource efficiency (paper: 3).
    pub c_efficiency: usize,
    /// Max offload patterns actually compiled+measured (paper: 4).
    pub d_patterns: usize,
    /// Reject patterns whose combined resource fraction exceeds this
    /// (paper: "上限値に納まらない場合は、その組合せパターンは作らない").
    pub resource_cap: f64,
    /// Verification-environment compile lanes.  The paper compiles
    /// sequentially on one machine (≈3 h per pattern, ~half a day for 4).
    pub compile_parallelism: usize,
    /// GA population for measurement-driven backends (GPU; the
    /// [Yamato 2018] flow the mixed-destination search reuses).
    pub ga_population: usize,
    /// GA generations for measurement-driven backends (GPU).
    pub ga_generations: usize,
    /// Function-block co-search mode (`flopt --blocks {off,on,only}`;
    /// the paper's loop-only flow is `Off`, the default).
    pub block_mode: BlockMode,
}

impl Default for SearchConfig {
    fn default() -> Self {
        Self {
            a_intensity: 5,
            b_unroll: 1,
            c_efficiency: 3,
            d_patterns: 4,
            resource_cap: 0.85,
            compile_parallelism: 1,
            ga_population: 8,
            ga_generations: 5,
            block_mode: BlockMode::Off,
        }
    }
}

/// One machine row of the paper's Fig 3 environment table.
#[derive(Debug, Clone)]
pub struct Machine {
    /// Role of the machine in the testbed.
    pub name: &'static str,
    /// Chassis / model.
    pub hardware: &'static str,
    /// CPU part and clock.
    pub cpu: &'static str,
    /// Installed memory.
    pub ram: &'static str,
    /// FPGA board (`-` when absent).
    pub fpga: &'static str,
    /// Operating system.
    pub os: &'static str,
    /// FPGA acceleration stack version (`-` when absent).
    pub accel_stack: &'static str,
}

/// The paper's Fig 3 testbed (what our simulators are calibrated to).
pub const FIG3_TESTBED: &[Machine] = &[
    Machine {
        name: "Verification machine",
        hardware: "Dell PowerEdge R740",
        cpu: "Intel Xeon Bronze 3104 (6C/1.7GHz)",
        ram: "32GB RDIMM DDR4-2666 x2",
        fpga: "Intel PAC with Intel Arria10 GX FPGA",
        os: "CentOS 7.4",
        accel_stack: "Intel Acceleration Stack 1.2",
    },
    Machine {
        name: "Running environment",
        hardware: "Dell PowerEdge R740",
        cpu: "Intel Xeon Bronze 3104 (6C/1.7GHz)",
        ram: "32GB RDIMM DDR4-2666 x2",
        fpga: "Intel PAC with Intel Arria10 GX FPGA",
        os: "CentOS 7.4",
        accel_stack: "Intel Acceleration Stack 1.2",
    },
    Machine {
        name: "Client",
        hardware: "HP ProBook 470 G3",
        cpu: "Intel Core i5-6200U @2.3GHz",
        ram: "8GB",
        fpga: "-",
        os: "Windows 7 Professional",
        accel_stack: "-",
    },
];

impl fmt::Display for Machine {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{:<22} | {:<22} | {:<34} | {:<8} | {:<38} | {:<10} | {}",
            self.name, self.hardware, self.cpu, self.ram, self.fpga, self.os,
            self.accel_stack
        )
    }
}

/// Render the Fig 3 table.
pub fn fig3_table() -> String {
    let mut out = String::from(
        "Name                   | Hardware               | CPU                                | RAM      | FPGA                                   | OS         | Accel stack\n",
    );
    out.push_str(&"-".repeat(150));
    out.push('\n');
    for m in FIG3_TESTBED {
        out.push_str(&m.to_string());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults() {
        let c = SearchConfig::default();
        assert_eq!(
            (c.a_intensity, c.b_unroll, c.c_efficiency, c.d_patterns),
            (5, 1, 3, 4),
            "must match the paper's §5.1.2 evaluation conditions"
        );
        assert_eq!(c.compile_parallelism, 1, "paper compiles sequentially");
    }

    #[test]
    fn fig3_has_three_machines() {
        assert_eq!(FIG3_TESTBED.len(), 3);
        assert!(fig3_table().contains("Arria10"));
        assert!(fig3_table().contains("Client"));
    }
}
