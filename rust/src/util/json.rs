//! Minimal JSON: enough to read `artifacts/manifest.json` and to emit the
//! structured reports the benches write.  No external crates (offline
//! build); the grammar is full RFC 8259 minus `\u` surrogate pairs.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (always stored as `f64`).
    Num(f64),
    /// String value.
    Str(String),
    /// Array value.
    Arr(Vec<Json>),
    /// Object value (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object member lookup (`None` for non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric payload truncated to `usize`, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    /// Array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object members, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    /// Byte offset of the error in the input.
    pub offset: usize,
    /// Human-readable description.
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

struct P<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> P<'a> {
    fn err<T>(&self, m: &str) -> Result<T, JsonError> {
        Err(JsonError { offset: self.i, message: m.into() })
    }

    fn ws(&mut self) {
        while matches!(self.b.get(self.i), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.b.get(self.i) == Some(&c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected `{}`", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.ws();
        match self.b.get(self.i) {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c.is_ascii_digit() || *c == b'-' => self.number(),
            _ => self.err("expected value"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            self.err(&format!("expected `{word}`"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.b.get(self.i) == Some(&b'-') {
            self.i += 1;
        }
        while matches!(self.b.get(self.i),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or(JsonError { offset: start, message: "bad number".into() })
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.b.get(self.i) {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = match self.b.get(self.i) {
                        Some(b'n') => '\n',
                        Some(b't') => '\t',
                        Some(b'r') => '\r',
                        Some(b'"') => '"',
                        Some(b'\\') => '\\',
                        Some(b'/') => '/',
                        Some(b'b') => '\u{8}',
                        Some(b'f') => '\u{c}',
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .and_then(char::from_u32);
                            match hex {
                                Some(c) => {
                                    self.i += 4;
                                    c
                                }
                                None => return self.err("bad \\u escape"),
                            }
                        }
                        _ => return self.err("bad escape"),
                    };
                    out.push(c);
                    self.i += 1;
                }
                Some(_) => {
                    // advance one UTF-8 codepoint
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| JsonError { offset: self.i, message: "bad utf-8".into() })?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
                None => return self.err("unterminated string"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.b.get(self.i) == Some(&b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return self.err("expected `,` or `]`"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.b.get(self.i) == Some(&b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return self.err("expected `,` or `}`"),
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = P { b: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return p.err("trailing characters");
    }
    Ok(v)
}

/// Serialize (compact).
pub fn write(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write(&Json::Str(k.clone()), out);
                out.push(':');
                write(x, out);
            }
            out.push('}');
        }
    }
}

/// Serialize to a new string.
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write(v, &mut s);
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_shape() {
        let doc = r#"{
            "tdfir_fpga": {
                "file": "tdfir_fpga.hlo.txt",
                "inputs": [{"shape": [4096], "dtype": "float32"}],
                "num_outputs": 2
            }
        }"#;
        let j = parse(doc).unwrap();
        let entry = j.get("tdfir_fpga").unwrap();
        assert_eq!(entry.get("file").unwrap().as_str(), Some("tdfir_fpga.hlo.txt"));
        assert_eq!(entry.get("num_outputs").unwrap().as_usize(), Some(2));
        let inputs = entry.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs[0].get("shape").unwrap().as_arr().unwrap()[0].as_usize(), Some(4096));
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,-3],"b":"x\ny","c":true,"d":null,"e":{}}"#;
        let j = parse(doc).unwrap();
        assert_eq!(parse(&to_string(&j)).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse(r#"{"a":}"#).is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = parse(r#""éテ""#).unwrap();
        assert_eq!(j.as_str(), Some("éテ"));
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(parse("0").unwrap().as_f64(), Some(0.0));
    }
}
