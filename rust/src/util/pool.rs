//! Fixed-size worker thread pool — the verification environment's compile
//! farm and the batch offload service run on it (tokio is unavailable
//! offline; plain threads + channels express the same leader/worker
//! structure).
//!
//! Panic safety: a panicking job must neither kill its worker nor wedge
//! the pool.  Workers catch unwinds, so the pool keeps draining jobs and
//! `Drop` always joins cleanly; [`Pool::map`] captures each job's panic
//! payload and re-raises the first one (by input order) on the submitting
//! thread, so a fleet-wide `map` fails loudly instead of hanging.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

use crate::obs::Recorder;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads executing boxed jobs FIFO.
pub struct Pool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    /// Job-scheduling metrics sink.  Counters only, updated from the
    /// *submitting* thread, so the exported totals are independent of
    /// worker count and interleaving.
    obs: Option<Arc<Recorder>>,
}

impl Pool {
    /// Spawn `n` workers (`n >= 1`).
    pub fn new(n: usize) -> Self {
        Self::build(n, None)
    }

    /// Spawn `n` workers that report job-scheduling metrics
    /// (`pool.jobs_submitted`, `pool.jobs_completed`, `pool.map_batch`)
    /// to `obs`.  Deliberately no worker-count metric: job totals are a
    /// function of the workload, so the snapshot stays byte-identical
    /// across `--pool` sizes.
    pub fn with_obs(n: usize, obs: Arc<Recorder>) -> Self {
        Self::build(n, Some(obs))
    }

    fn build(n: usize, obs: Option<Arc<Recorder>>) -> Self {
        assert!(n >= 1, "pool needs at least one worker");
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("flopt-worker-{i}"))
                    .spawn(move || loop {
                        // the guard drops at the end of this statement, so
                        // the job itself runs unlocked and a panicking job
                        // can never poison the receiver mutex
                        let job = rx.lock().unwrap_or_else(|p| p.into_inner()).recv();
                        match job {
                            // a raw `submit` has nowhere to surface a
                            // panic — swallow it and keep the worker alive
                            Ok(job) => drop(catch_unwind(AssertUnwindSafe(|| job()))),
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { tx: Some(tx), workers, obs }
    }

    /// Submit a job.  A panic inside the job is caught by the worker
    /// (use [`Pool::map`] when the submitter must observe failures).
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        if let Some(obs) = &self.obs {
            obs.count("pool.jobs_submitted", 1);
        }
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("workers alive");
    }

    /// Run all `tasks` on the pool and collect results in input order.
    ///
    /// If any job panics, the panic is propagated to the caller
    /// (re-raised with the original payload, first failing input index
    /// wins) after every job has finished — the pool itself stays usable.
    pub fn map<T, R>(
        &self,
        tasks: Vec<T>,
        f: impl Fn(T) -> R + Send + Sync + 'static,
    ) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
    {
        let n = tasks.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, thread::Result<R>)>();
        for (i, t) in tasks.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.submit(move || {
                let r = catch_unwind(AssertUnwindSafe(|| f(t)));
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<thread::Result<R>>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            // every job sends exactly once (panics are caught above), so
            // this cannot hang
            let (i, r) = rrx.recv().expect("pool workers alive");
            out[i] = Some(r);
        }
        let mut results = Vec::with_capacity(n);
        for slot in out {
            match slot.expect("all slots filled") {
                Ok(r) => results.push(r),
                Err(payload) => resume_unwind(payload),
            }
        }
        if let Some(obs) = &self.obs {
            obs.count("pool.jobs_completed", n as u64);
            obs.observe("pool.map_batch", n as f64);
        }
        results
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = Pool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = Pool::new(3);
        let out = pool.map((0..50).collect(), |x: i32| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_is_sequential_pool() {
        let pool = Pool::new(1);
        let out = pool.map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn panicking_jobs_do_not_kill_workers_or_hang_drop() {
        let pool = Pool::new(2);
        // more panicking jobs than workers: pre-fix, this killed the
        // whole pool and any later map would hang
        for _ in 0..8 {
            pool.submit(|| panic!("job exploded"));
        }
        let out = pool.map(vec![10, 20, 30], |x| x + 1);
        assert_eq!(out, vec![11, 21, 31]);
        drop(pool); // must join cleanly, not hang
    }

    #[test]
    fn with_obs_counts_jobs_from_the_submitting_thread() {
        let rec = Arc::new(Recorder::new(true));
        let pool = Pool::with_obs(3, Arc::clone(&rec));
        let out = pool.map((0..10).collect(), |x: i32| x + 1);
        assert_eq!(out.len(), 10);
        assert_eq!(rec.counter("pool.jobs_submitted"), 10);
        assert_eq!(rec.counter("pool.jobs_completed"), 10);
        let h = rec.histograms();
        let batch = h.iter().next().expect("map_batch histogram").1;
        assert_eq!(batch.count, 1);
        assert_eq!(batch.sum, 10.0);
    }

    #[test]
    fn map_propagates_the_panic_to_the_submitter() {
        let pool = Pool::new(2);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.map(vec![0, 1, 2, 3], |x: i32| {
                if x == 2 {
                    panic!("bad item {x}");
                }
                x
            })
        }));
        let payload = caught.expect_err("panic must reach the submitter");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("bad item 2"), "payload: {msg:?}");
        // the pool survives the failed map
        let out = pool.map(vec![1, 2], |x| x * 10);
        assert_eq!(out, vec![10, 20]);
    }
}
