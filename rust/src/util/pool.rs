//! Fixed-size worker thread pool — the verification environment's compile
//! farm runs simulated FPGA compiles on it (tokio is unavailable offline;
//! plain threads + channels express the same leader/worker structure).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads executing boxed jobs FIFO.
pub struct Pool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl Pool {
    /// Spawn `n` workers (`n >= 1`).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "pool needs at least one worker");
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("flopt-worker-{i}"))
                    .spawn(move || loop {
                        let job = rx.lock().expect("poisoned").recv();
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { tx: Some(tx), workers }
    }

    /// Submit a job.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("workers alive");
    }

    /// Run all `tasks` on the pool and collect results in input order.
    pub fn map<T, R>(
        &self,
        tasks: Vec<T>,
        f: impl Fn(T) -> R + Send + Sync + 'static,
    ) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
    {
        let n = tasks.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, R)>();
        for (i, t) in tasks.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.submit(move || {
                let r = f(t);
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("worker panicked");
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("all slots filled")).collect()
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = Pool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = Pool::new(3);
        let out = pool.map((0..50).collect(), |x: i32| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_is_sequential_pool() {
        let pool = Pool::new(1);
        let out = pool.map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }
}
