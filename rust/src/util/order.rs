//! NaN-safe, total-order comparison and selection helpers.
//!
//! Every selection hot path in the search (efficiency narrowing, winner
//! selection, GA elitism, fleet placement) used to sort or `max_by` with
//! `partial_cmp(..).unwrap()`, which panics the moment one degenerate
//! measurement produces a NaN — and, on exact ties, silently depends on
//! iterator order.  This module centralizes the replacement contract:
//!
//! * comparisons use [`f64::total_cmp`] (a total order — never panics);
//! * in sorts, **NaN always ranks last**, whether the sort is ascending
//!   or descending, so a poisoned value can never float to the front of
//!   a narrowing cut;
//! * selections ([`select_best`]) **reject NaN keys outright** and break
//!   exact ties with a caller-supplied deterministic key (pattern id,
//!   then submission order), so the winner is a pure function of the
//!   candidate set — identical across runs, pool sizes, and platforms.

use std::cmp::Ordering;

/// Ascending total order on `f64` with NaN sorted **last**.
///
/// For finite and infinite values this is exactly the familiar numeric
/// order (`total_cmp` agrees with `partial_cmp` there); NaN of either
/// sign is pushed behind everything else.
pub fn asc_nan_last(a: f64, b: f64) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        (false, false) => a.total_cmp(&b),
    }
}

/// Descending total order on `f64` with NaN sorted **last**.
pub fn desc_nan_last(a: f64, b: f64) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        (false, false) => b.total_cmp(&a),
    }
}

/// Pick the item with the highest **non-NaN** score; exact ties go to
/// the smallest `tie` key.  Items whose score is NaN are rejected
/// outright — a poisoned measurement can never be selected, and the
/// result is deterministic for any iteration order of equal-score items.
pub fn select_best<T, K: Ord>(
    items: impl IntoIterator<Item = T>,
    score: impl Fn(&T) -> f64,
    tie: impl Fn(&T) -> K,
) -> Option<T> {
    let mut best: Option<(f64, K, T)> = None;
    for item in items {
        let s = score(&item);
        if s.is_nan() {
            continue; // degenerate measurement: never a winner
        }
        let replace = match &best {
            None => true,
            Some((bs, bk, _)) => match s.total_cmp(bs) {
                Ordering::Greater => true,
                Ordering::Less => false,
                Ordering::Equal => tie(&item) < *bk,
            },
        };
        if replace {
            let k = tie(&item);
            best = Some((s, k, item));
        }
    }
    best.map(|(_, _, item)| item)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nan_sorts_last_in_both_directions() {
        let mut v = vec![2.0, f64::NAN, 1.0, 3.0];
        v.sort_by(|a, b| desc_nan_last(*a, *b));
        assert_eq!(&v[..3], &[3.0, 2.0, 1.0]);
        assert!(v[3].is_nan());
        v.sort_by(|a, b| asc_nan_last(*a, *b));
        assert_eq!(&v[..3], &[1.0, 2.0, 3.0]);
        assert!(v[3].is_nan());
    }

    #[test]
    fn infinities_order_normally() {
        let mut v = vec![0.0, f64::INFINITY, f64::NEG_INFINITY];
        v.sort_by(|a, b| asc_nan_last(*a, *b));
        assert_eq!(v, vec![f64::NEG_INFINITY, 0.0, f64::INFINITY]);
    }

    #[test]
    fn select_best_rejects_nan_and_breaks_ties_deterministically() {
        // NaN never wins, even when it is the only "largest" value
        let items = vec![("a", f64::NAN), ("b", 2.0), ("c", 2.0), ("d", 1.0)];
        let w = select_best(items.iter(), |x| x.1, |x| x.0).unwrap();
        assert_eq!(w.0, "b", "tie between b and c goes to the smaller key");

        // identical result regardless of iteration order
        let mut rev = items.clone();
        rev.reverse();
        let w2 = select_best(rev.iter(), |x| x.1, |x| x.0).unwrap();
        assert_eq!(w2.0, "b");

        // all-NaN input selects nothing (and does not panic)
        let poisoned = vec![("x", f64::NAN), ("y", f64::NAN)];
        assert!(select_best(poisoned.iter(), |x| x.1, |x| x.0).is_none());
    }
}
