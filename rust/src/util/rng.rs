//! Deterministic PRNG: SplitMix64 seeding an xoshiro256** core.
//!
//! Used by the GA baseline, the workload generators, and the in-tree
//! property tests.  Deterministic by construction — every consumer passes
//! an explicit seed so runs are reproducible.

/// xoshiro256** with SplitMix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator (SplitMix64 expands the seed into the state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Next raw 64-bit output of the xoshiro256** core.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in `[0, n)`; `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // rejection-free Lemire-style reduction is overkill here
        self.next_u64() % n
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Bernoulli draw: `true` with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_is_centered() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
