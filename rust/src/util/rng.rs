//! Deterministic PRNG: SplitMix64 seeding an xoshiro256** core.
//!
//! Used by the GA baseline, the workload generators, and the in-tree
//! property tests.  Deterministic by construction — every consumer passes
//! an explicit seed so runs are reproducible.

/// xoshiro256** with SplitMix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator (SplitMix64 expands the seed into the state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Next raw 64-bit output of the xoshiro256** core.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    ///
    /// The naive `lo + f64() * (hi - lo)` can round **up to exactly
    /// `hi`** when the draw is close to 1 and the arithmetic rounds (e.g.
    /// `lo = 0.0, hi = 1e-300`), violating the half-open contract; such
    /// draws are clamped to the largest representable value below `hi`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        let v = lo + self.f64() * (hi - lo);
        if v >= hi && hi > lo {
            next_below(hi)
        } else {
            v
        }
    }

    /// Uniform integer in `[0, n)`; `n` must be > 0.
    ///
    /// Lemire's widening-multiply reduction with rejection: the plain
    /// `next_u64() % n` used before this is **modulo-biased** — for `n`
    /// not a power of two the low `2^64 mod n` values are more likely
    /// than the rest (severely so for `n` near `2^63`), which skews GA
    /// tournament picks and workload shuffles.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut low = m as u64;
        if low < n {
            // reject draws from the short (biased) final interval
            let threshold = n.wrapping_neg() % n;
            while low < threshold {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                low = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Bernoulli draw: `true` with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

/// The largest representable `f64` strictly below a positive, negative,
/// or zero finite `hi` (a `f64::next_down` stand-in for the pinned MSRV).
fn next_below(hi: f64) -> f64 {
    debug_assert!(hi.is_finite());
    if hi == 0.0 {
        -f64::from_bits(1) // largest value below ±0.0 is -min_subnormal
    } else if hi > 0.0 {
        f64::from_bits(hi.to_bits() - 1)
    } else {
        f64::from_bits(hi.to_bits() + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_is_centered() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    /// Regression for the modulo-bias fix: with `n = 3·2^62`, the old
    /// `next_u64() % n` reduction returned values below `2^62` with
    /// probability 1/2 instead of 1/3 (both halves of the 2^64 input
    /// space land there).  The Lemire reduction must be uniform.
    #[test]
    fn below_has_no_modulo_bias_for_large_n() {
        let n: u64 = 3 << 62;
        let bucket = 1u64 << 62; // first third of [0, n)
        let mut r = Rng::new(11);
        let draws = 30_000;
        let hits = (0..draws).filter(|_| r.below(n) < bucket).count() as f64;
        let frac = hits / draws as f64;
        assert!(
            (frac - 1.0 / 3.0).abs() < 0.02,
            "P(v < n/3) = {frac}, expected ≈ 1/3 (0.5 would mean modulo bias)"
        );
    }

    #[test]
    fn below_small_n_buckets_are_level() {
        let mut r = Rng::new(13);
        let mut counts = [0u32; 7];
        let draws = 70_000;
        for _ in 0..draws {
            counts[r.below(7) as usize] += 1;
        }
        for (i, c) in counts.iter().enumerate() {
            let expected = draws as f64 / 7.0;
            assert!(
                (*c as f64 - expected).abs() < expected * 0.05,
                "bucket {i}: {c} vs expected {expected}"
            );
        }
    }

    /// Regression for the `hi`-exclusivity fix: with a subnormal span,
    /// `lo + f64()·(hi − lo)` rounds up to exactly `hi` for roughly half
    /// the draws — the clamp must keep every draw strictly below `hi`.
    #[test]
    fn range_f64_excludes_hi_even_under_rounding() {
        let mut r = Rng::new(17);
        let hi = f64::from_bits(1); // smallest positive subnormal
        for _ in 0..256 {
            let v = r.range_f64(0.0, hi);
            assert!(v < hi, "draw {v} must stay below hi {hi}");
            assert!(v >= 0.0);
        }
        // sane spans are untouched by the clamp
        let mut r2 = Rng::new(19);
        for _ in 0..1000 {
            let v = r2.range_f64(2.0, 3.0);
            assert!((2.0..3.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
