//! In-tree utility substrates.
//!
//! The build environment is fully offline (only the `xla` crate closure +
//! `anyhow` are vendored), so the small infrastructure pieces a crates.io
//! project would pull in are implemented here instead:
//!
//! * [`json`] — minimal JSON parser/writer (reads `artifacts/manifest.json`);
//! * [`rng`] — SplitMix64/xoshiro-style deterministic PRNG (GA baseline,
//!   property tests, workload generators);
//! * [`pool`] — fixed-size worker thread pool (the verification
//!   environment's compile farm);
//! * [`bench`] — tiny measurement harness (criterion stand-in) used by
//!   `benches/*.rs`;
//! * [`order`] — NaN-safe total-order comparators and the deterministic
//!   winner-selection rule every selection hot path routes through;
//! * [`intern`] — the global identifier interner ([`intern::Symbol`])
//!   the whole analysis front end keys on.

pub mod bench;
pub mod intern;
pub mod json;
pub mod order;
pub mod pool;
pub mod rng;
