//! Global string interner: [`Symbol`] is a `u32` handle to a unique,
//! leaked string.  Identifiers are interned once by the lexer and flow
//! through the AST, the IR analyses, and the interpreter as plain
//! integers — equality and hashing are integer operations, and the maps
//! that used to key on `String` key on `Symbol` instead.
//!
//! Two properties are load-bearing for byte-identity of all downstream
//! output (see DESIGN.md §3h):
//!
//! * `Ord` compares the *resolved strings* (with an id fast path for
//!   equality), so every `BTreeMap<Symbol, _>` / `BTreeSet<Symbol>`
//!   iterates in exactly the lexicographic order the `String`-keyed
//!   maps did.  The interner guarantees distinct ids ⇔ distinct
//!   strings, so the fast path agrees with the string comparison.
//! * `Display`/`Debug` render the original spelling, so pretty-printed
//!   source, kernels, and reports are unchanged.
//!
//! `Symbol` deliberately does **not** implement `Borrow<str>`: it
//! hashes by id while `str` hashes by content, and a `Borrow` impl
//! would silently break `HashMap` lookups.

use std::collections::HashMap;
use std::fmt;
use std::sync::{Mutex, OnceLock};

/// Interned identifier: a cheap, `Copy` handle to a unique string.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Symbol(u32);

struct Interner {
    map: HashMap<&'static str, u32>,
    strs: Vec<&'static str>,
}

fn interner() -> &'static Mutex<Interner> {
    static INTERNER: OnceLock<Mutex<Interner>> = OnceLock::new();
    INTERNER.get_or_init(|| {
        Mutex::new(Interner { map: HashMap::new(), strs: Vec::new() })
    })
}

impl Symbol {
    /// Intern `name`, returning the canonical handle for its spelling.
    /// Interning the same spelling twice returns the same `Symbol`.
    pub fn intern(name: &str) -> Symbol {
        let mut it = interner().lock().expect("interner lock poisoned");
        if let Some(&id) = it.map.get(name) {
            return Symbol(id);
        }
        let id = u32::try_from(it.strs.len()).expect("interner overflow");
        // Leak one copy per distinct spelling; identifiers are a small,
        // bounded set for the process lifetime.
        let leaked: &'static str = Box::leak(name.to_owned().into_boxed_str());
        it.strs.push(leaked);
        it.map.insert(leaked, id);
        Symbol(id)
    }

    /// The original spelling this symbol was interned from.
    pub fn as_str(self) -> &'static str {
        interner().lock().expect("interner lock poisoned").strs[self.0 as usize]
    }
}

impl Ord for Symbol {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Fast path: same id ⇔ same string (interner invariant), so the
        // two branches can never disagree.
        if self.0 == other.0 {
            return std::cmp::Ordering::Equal;
        }
        self.as_str().cmp(other.as_str())
    }
}

impl PartialOrd for Symbol {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl fmt::Display for Symbol {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl fmt::Debug for Symbol {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:?}", self.as_str())
    }
}

impl From<&str> for Symbol {
    fn from(s: &str) -> Symbol {
        Symbol::intern(s)
    }
}

impl From<&String> for Symbol {
    fn from(s: &String) -> Symbol {
        Symbol::intern(s)
    }
}

impl From<String> for Symbol {
    fn from(s: String) -> Symbol {
        Symbol::intern(&s)
    }
}

impl PartialEq<str> for Symbol {
    fn eq(&self, other: &str) -> bool {
        self.as_str() == other
    }
}

impl PartialEq<&str> for Symbol {
    fn eq(&self, other: &&str) -> bool {
        self.as_str() == *other
    }
}

impl PartialEq<Symbol> for str {
    fn eq(&self, other: &Symbol) -> bool {
        self == other.as_str()
    }
}

impl PartialEq<Symbol> for &str {
    fn eq(&self, other: &Symbol) -> bool {
        *self == other.as_str()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interning_is_idempotent() {
        let a = Symbol::intern("alpha_interner_test");
        let b = Symbol::intern("alpha_interner_test");
        assert_eq!(a, b);
        assert_eq!(a.as_str(), "alpha_interner_test");
    }

    #[test]
    fn distinct_spellings_get_distinct_symbols() {
        assert_ne!(Symbol::intern("intern_x"), Symbol::intern("intern_y"));
    }

    #[test]
    fn ord_is_lexicographic_regardless_of_intern_order() {
        // interned in reverse lexicographic order on purpose
        let z = Symbol::intern("zz_intern_ord");
        let a = Symbol::intern("aa_intern_ord");
        let m = Symbol::intern("mm_intern_ord");
        let mut v = [z, a, m];
        v.sort();
        let spelled: Vec<&str> = v.iter().map(|s| s.as_str()).collect();
        assert_eq!(spelled, vec!["aa_intern_ord", "mm_intern_ord", "zz_intern_ord"]);
    }

    #[test]
    fn btree_iteration_matches_string_order() {
        use std::collections::BTreeSet;
        let names = ["out", "acc", "in", "taps", "a0"];
        let syms: BTreeSet<Symbol> = names.iter().map(|n| Symbol::intern(n)).collect();
        let mut sorted = names.to_vec();
        sorted.sort_unstable();
        let resolved: Vec<&str> = syms.iter().map(|s| s.as_str()).collect();
        assert_eq!(resolved, sorted);
    }

    #[test]
    fn display_and_debug_render_the_spelling() {
        let s = Symbol::intern("spelled_out");
        assert_eq!(format!("{s}"), "spelled_out");
        assert_eq!(format!("{s:?}"), "\"spelled_out\"");
    }

    #[test]
    fn compares_with_plain_strs() {
        let s = Symbol::intern("cmp_me");
        assert!(s == "cmp_me");
        assert!("cmp_me" == s);
        assert!(s != "cmp_you");
    }
}
