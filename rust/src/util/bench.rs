//! Tiny measurement harness (criterion is unavailable offline).
//!
//! `benches/*.rs` are `harness = false` binaries; they use [`time_it`] for
//! wall-clock medians and print the paper-table rows directly.

use std::time::Instant;

/// Result of a timed measurement.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    /// Median wall-clock seconds per iteration.
    pub median_s: f64,
    /// Fastest observed iteration.
    pub min_s: f64,
    /// Slowest observed iteration.
    pub max_s: f64,
    /// Number of timed iterations.
    pub iters: usize,
}

/// Run `f` for `iters` timed iterations (after one warmup) and report
/// median/min/max wall-clock seconds.
pub fn time_it<T>(iters: usize, mut f: impl FnMut() -> T) -> Timing {
    assert!(iters >= 1);
    let _warmup = f();
    let mut samples: Vec<f64> = (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            let r = f();
            let dt = t0.elapsed().as_secs_f64();
            std::hint::black_box(r);
            dt
        })
        .collect();
    samples.sort_by(|a, b| a.total_cmp(b));
    Timing {
        median_s: samples[samples.len() / 2],
        min_s: samples[0],
        max_s: *samples.last().unwrap(),
        iters,
    }
}

/// Common bench-binary arguments (`harness = false` targets).
#[derive(Debug, Clone, Default)]
pub struct BenchArgs {
    /// Run the searches at CI test scale instead of full paper scale.
    pub test_scale: bool,
    /// Write a JSON report to this path when set.
    pub report: Option<String>,
}

/// Parse `--test-scale` / `--report <path>` from the process arguments,
/// ignoring whatever else `cargo bench` passes through.
pub fn parse_bench_args() -> BenchArgs {
    let args: Vec<String> = std::env::args().skip(1).collect();
    parse_bench_args_from(&args)
}

/// [`parse_bench_args`] over an explicit argument list.  A `--report`
/// followed by another flag (or nothing) is treated as having no path —
/// the next flag is still honored rather than swallowed as a filename.
pub fn parse_bench_args_from(args: &[String]) -> BenchArgs {
    let mut out = BenchArgs::default();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--test-scale" => out.test_scale = true,
            "--report" => {
                if let Some(v) = args.get(i + 1).filter(|v| !v.starts_with("--")) {
                    out.report = Some(v.clone());
                    i += 1;
                }
            }
            _ => {}
        }
        i += 1;
    }
    out
}

/// Pretty seconds (auto unit).
pub fn fmt_s(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

/// Simulated hours → human string (benches report the paper's compile
/// hours from the simulated clock).
pub fn fmt_sim_hours(h: f64) -> String {
    if h >= 1.0 {
        format!("{h:.1} h")
    } else {
        format!("{:.0} min", h * 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_is_sane() {
        let t = time_it(5, || {
            std::hint::black_box((0..1000).sum::<u64>())
        });
        assert!(t.min_s <= t.median_s && t.median_s <= t.max_s);
        assert_eq!(t.iters, 5);
    }

    #[test]
    fn bench_args_parse() {
        let s = |v: &[&str]| v.iter().map(|x| x.to_string()).collect::<Vec<_>>();
        let a = parse_bench_args_from(&s(&["--test-scale", "--report", "out.json"]));
        assert!(a.test_scale);
        assert_eq!(a.report.as_deref(), Some("out.json"));
        // --report followed by a flag: no path, the flag still applies
        let b = parse_bench_args_from(&s(&["--report", "--test-scale"]));
        assert!(b.test_scale);
        assert!(b.report.is_none());
        // unknown cargo-bench passthrough args are ignored
        let c = parse_bench_args_from(&s(&["--bench", "anything"]));
        assert!(!c.test_scale);
        assert!(c.report.is_none());
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_s(2.0).ends_with(" s"));
        assert!(fmt_s(2e-3).ends_with(" ms"));
        assert!(fmt_s(2e-6).ends_with(" µs"));
        assert!(fmt_s(2e-9).ends_with(" ns"));
        assert_eq!(fmt_sim_hours(3.0), "3.0 h");
        assert_eq!(fmt_sim_hours(0.5), "30 min");
    }
}
