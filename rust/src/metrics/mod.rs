//! Simulated-clock accounting.
//!
//! The search's *automation time* (paper §5.2: ≈3 h per FPGA compile,
//! ≈half a day for 4 patterns) is tracked on a simulated clock, decoupled
//! from the milliseconds the simulators actually take.  The compile farm
//! models makespan over `lanes` parallel compile slots (paper: 1 lane).
//!
//! Every clock carries an [`obs::Recorder`] (DESIGN.md §3i): direct
//! charges double as spans on the simulated timeline (serial work on
//! the wall-clock axis, compile jobs on their lane's occupancy axis),
//! while [`SimClock::replay`] re-accounts time *silently* — replayed
//! work was already recorded by the clock that performed it, and the
//! batch service folds those recorders in with
//! [`obs::Recorder::merge_from`] instead of re-emitting spans.

use std::sync::{Arc, Mutex};

use crate::obs::{self, Recorder};
use crate::util::intern::Symbol;

/// A named simulated-time event.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// What the time was spent on (interned — replay never reallocates).
    pub label: Symbol,
    /// Simulated duration in seconds.
    pub sim_seconds: f64,
    /// lane the event ran on (compile farm), 0 for serial phases
    pub lane: usize,
    /// Was this a compile-farm job (vs. serial automation time)?
    pub compile: bool,
}

/// Simulated clock with parallel-lane makespan accounting.
#[derive(Debug)]
pub struct SimClock {
    inner: Mutex<Inner>,
    obs: Arc<Recorder>,
}

#[derive(Debug)]
struct Inner {
    /// per-lane busy-until times
    lanes: Vec<f64>,
    /// serial time accumulated outside the farm (analysis, measurement)
    serial: f64,
    events: Vec<Event>,
}

impl SimClock {
    /// A clock with `lanes` parallel compile slots (`lanes >= 1`).
    pub fn new(lanes: usize) -> Self {
        Self::with_recorder(lanes, Arc::new(Recorder::new(true)))
    }

    /// A clock whose recorder is disabled: every span/metric call is a
    /// cheap no-op.  The `obs_overhead` bench prices tracing by running
    /// the same search on a traced and an untraced clock.
    pub fn new_untraced(lanes: usize) -> Self {
        Self::with_recorder(lanes, Arc::new(Recorder::new(false)))
    }

    /// A clock sharing an existing recorder.
    pub fn with_recorder(lanes: usize, obs: Arc<Recorder>) -> Self {
        assert!(lanes >= 1);
        Self {
            inner: Mutex::new(Inner {
                lanes: vec![0.0; lanes],
                serial: 0.0,
                events: Vec::new(),
            }),
            obs,
        }
    }

    /// The clock's span/metrics recorder.
    pub fn obs(&self) -> &Arc<Recorder> {
        &self.obs
    }

    /// Open a span at the current simulated time (close it with
    /// [`SimClock::span_end`]).
    pub fn span(&self, name: &str, cat: &str) -> obs::OpenSpan {
        self.obs.begin(name, cat, self.total_seconds())
    }

    /// Close a span opened by [`SimClock::span`] at the current
    /// simulated time.
    pub fn span_end(&self, span: obs::OpenSpan) {
        self.obs.end(span, self.total_seconds());
    }

    /// Record an instant marker span at the current simulated time
    /// (cache hits, admission decisions, …).
    pub fn mark(&self, name: &str, cat: &str) {
        self.obs.mark(name, cat, self.total_seconds());
    }

    fn charge_serial(&self, label: Symbol, sim_seconds: f64, trace: bool) {
        let mut g = self.inner.lock().expect("poisoned");
        if trace {
            let start = g.serial + g.lanes.iter().cloned().fold(0.0, f64::max);
            self.obs.record(label, "clock.serial", start, sim_seconds, 0);
        }
        g.serial += sim_seconds;
        g.events.push(Event { label, sim_seconds, lane: 0, compile: false });
    }

    fn charge_compile(&self, label: Symbol, sim_seconds: f64, trace: bool) -> usize {
        let mut g = self.inner.lock().expect("poisoned");
        // total_cmp: lane times are always finite, but the scheduler must
        // never be able to panic; ties keep the first (lowest-index) lane
        let lane = g
            .lanes
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap();
        if trace {
            let start = g.lanes[lane];
            self.obs
                .record(label, "clock.compile", start, sim_seconds, lane as u32 + 1);
        }
        g.lanes[lane] += sim_seconds;
        g.events.push(Event { label, sim_seconds, lane, compile: true });
        lane
    }

    /// Record serial work (code analysis, precompile, measurement, ...).
    pub fn advance_serial(&self, label: &str, sim_seconds: f64) {
        self.charge_serial(Symbol::intern(label), sim_seconds, true);
    }

    /// Schedule a compile job on the earliest-free lane; returns the lane.
    pub fn schedule_compile(&self, label: &str, sim_seconds: f64) -> usize {
        self.charge_compile(Symbol::intern(label), sim_seconds, true)
    }

    /// Re-account a recorded event stream onto this clock, preserving
    /// serial-vs-compile semantics.  The batch service runs every search
    /// on a private clock and replays the events of the work it actually
    /// performed onto the shared batch clock in deterministic submission
    /// order, so batch accounting is independent of worker count.
    ///
    /// Replay is span-silent: labels are already interned `Symbol`s
    /// (nothing allocates on this hot path) and the spans for the
    /// replayed work live on the recorder of the clock that ran it.
    pub fn replay(&self, events: &[Event]) {
        for e in events {
            if e.compile {
                self.charge_compile(e.label, e.sim_seconds, false);
            } else {
                self.charge_serial(e.label, e.sim_seconds, false);
            }
        }
    }

    /// Total simulated wall-clock: serial time + compile-farm makespan.
    pub fn total_seconds(&self) -> f64 {
        let g = self.inner.lock().expect("poisoned");
        g.serial + g.lanes.iter().cloned().fold(0.0, f64::max)
    }

    /// [`SimClock::total_seconds`] in hours.
    pub fn total_hours(&self) -> f64 {
        self.total_seconds() / 3600.0
    }

    /// Sum of compile-lane time (CPU-hours spent compiling, not makespan).
    pub fn compile_lane_seconds(&self) -> f64 {
        let g = self.inner.lock().expect("poisoned");
        g.lanes.iter().sum()
    }

    /// Snapshot of every recorded event, in submission order.
    pub fn events(&self) -> Vec<Event> {
        self.inner.lock().expect("poisoned").events.clone()
    }

    /// Start a compile-lane meter: attributes the lane-seconds burned
    /// from this point on (the mixed-destination search meters each
    /// backend's share of one shared clock).
    pub fn compile_meter(&self) -> CompileMeter<'_> {
        CompileMeter { clock: self, start_lane_s: self.compile_lane_seconds() }
    }

    /// Start a span meter covering both serial time and compile-lane
    /// time: the staged pipeline stamps each `SearchTrace` with the
    /// simulated time *that search* added, so a cached trace replays the
    /// same numbers regardless of what else ran on the clock.
    pub fn span_meter(&self) -> SpanMeter<'_> {
        SpanMeter {
            clock: self,
            start_total_s: self.total_seconds(),
            start_lane_s: self.compile_lane_seconds(),
        }
    }
}

/// Span accounting over a [`SimClock`]: compile-lane time burned since
/// [`SimClock::compile_meter`] was called.
#[derive(Debug)]
pub struct CompileMeter<'c> {
    clock: &'c SimClock,
    start_lane_s: f64,
}

impl CompileMeter<'_> {
    /// Compile-lane seconds burned since the meter started.
    pub fn lane_seconds(&self) -> f64 {
        self.clock.compile_lane_seconds() - self.start_lane_s
    }

    /// [`CompileMeter::lane_seconds`] in hours.
    pub fn lane_hours(&self) -> f64 {
        self.lane_seconds() / 3600.0
    }
}

/// Span accounting over a [`SimClock`]: simulated wall-clock *and*
/// compile-lane time added since [`SimClock::span_meter`] was called.
#[derive(Debug)]
pub struct SpanMeter<'c> {
    clock: &'c SimClock,
    start_total_s: f64,
    start_lane_s: f64,
}

impl SpanMeter<'_> {
    /// Simulated wall-clock seconds added since the meter started.
    pub fn total_seconds(&self) -> f64 {
        self.clock.total_seconds() - self.start_total_s
    }

    /// [`SpanMeter::total_seconds`] in hours.
    pub fn total_hours(&self) -> f64 {
        self.total_seconds() / 3600.0
    }

    /// Compile-lane seconds added since the meter started.
    pub fn lane_seconds(&self) -> f64 {
        self.clock.compile_lane_seconds() - self.start_lane_s
    }

    /// [`SpanMeter::lane_seconds`] in hours.
    pub fn lane_hours(&self) -> f64 {
        self.lane_seconds() / 3600.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_accumulates() {
        let c = SimClock::new(1);
        c.advance_serial("analysis", 60.0);
        c.advance_serial("measure", 30.0);
        assert_eq!(c.total_seconds(), 90.0);
    }

    #[test]
    fn single_lane_compiles_are_sequential() {
        let c = SimClock::new(1);
        c.schedule_compile("p1", 3.0 * 3600.0);
        c.schedule_compile("p2", 3.0 * 3600.0);
        assert_eq!(c.total_hours(), 6.0);
    }

    #[test]
    fn parallel_lanes_give_makespan() {
        let c = SimClock::new(2);
        c.schedule_compile("p1", 3.0 * 3600.0);
        c.schedule_compile("p2", 3.0 * 3600.0);
        c.schedule_compile("p3", 3.0 * 3600.0);
        // 2 lanes, 3 jobs of 3h -> makespan 6h
        assert_eq!(c.total_hours(), 6.0);
        assert_eq!(c.compile_lane_seconds(), 9.0 * 3600.0);
    }

    #[test]
    fn compile_meter_attributes_spans() {
        let c = SimClock::new(2);
        c.schedule_compile("before", 3600.0);
        let meter = c.compile_meter();
        assert_eq!(meter.lane_seconds(), 0.0);
        c.schedule_compile("during", 7200.0);
        c.advance_serial("serial is not metered", 60.0);
        assert_eq!(meter.lane_seconds(), 7200.0);
        assert!((meter.lane_hours() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn events_recorded() {
        let c = SimClock::new(1);
        c.advance_serial("x", 1.0);
        c.schedule_compile("y", 2.0);
        let ev = c.events();
        assert_eq!(ev.len(), 2);
        assert_eq!(ev[1].label, "y");
        assert!(!ev[0].compile);
        assert!(ev[1].compile);
    }

    #[test]
    fn replay_reproduces_totals() {
        let src = SimClock::new(2);
        src.advance_serial("analysis", 150.0);
        src.schedule_compile("p1", 3.0 * 3600.0);
        src.schedule_compile("p2", 2.0 * 3600.0);
        src.advance_serial("measure", 10.0);

        let dst = SimClock::new(2);
        dst.replay(&src.events());
        assert_eq!(dst.total_seconds(), src.total_seconds());
        assert_eq!(dst.compile_lane_seconds(), src.compile_lane_seconds());
        assert_eq!(dst.events().len(), src.events().len());
    }

    #[test]
    fn span_meter_attributes_both_dimensions() {
        let c = SimClock::new(1);
        c.advance_serial("before", 100.0);
        c.schedule_compile("before-compile", 50.0);
        let m = c.span_meter();
        assert_eq!(m.total_seconds(), 0.0);
        assert_eq!(m.lane_seconds(), 0.0);
        c.advance_serial("during", 30.0);
        c.schedule_compile("during-compile", 7200.0);
        assert_eq!(m.total_seconds(), 30.0 + 7200.0);
        assert_eq!(m.lane_seconds(), 7200.0);
        assert!((m.lane_hours() - 2.0).abs() < 1e-12);
        assert!((m.total_hours() - (7230.0 / 3600.0)).abs() < 1e-12);
    }

    #[test]
    fn charges_double_as_spans_and_replay_is_silent() {
        let c = SimClock::new(2);
        c.advance_serial("analysis", 60.0);
        c.schedule_compile("compile p1", 3600.0);
        let spans = c.obs().spans();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].name, "analysis");
        assert_eq!(spans[0].cat, "clock.serial");
        assert_eq!(spans[0].lane, 0);
        assert_eq!(spans[1].name, "compile p1");
        assert_eq!(spans[1].cat, "clock.compile");
        assert_eq!(spans[1].lane, 1);
        assert_eq!(spans[1].dur_s, 3600.0);

        let dst = SimClock::new(2);
        dst.replay(&c.events());
        assert_eq!(dst.total_seconds(), c.total_seconds());
        assert!(dst.obs().spans().is_empty(), "replay must not re-emit spans");
    }

    #[test]
    fn untraced_clock_accounts_time_but_records_nothing() {
        let c = SimClock::new_untraced(1);
        c.advance_serial("analysis", 60.0);
        let sp = c.span("stage.analyze", "pipeline");
        c.span_end(sp);
        c.mark("cache.hit", "cache");
        c.obs().count("cache.hit.trace", 1);
        assert_eq!(c.total_seconds(), 60.0);
        assert_eq!(c.events().len(), 1);
        assert!(c.obs().spans().is_empty());
        assert_eq!(c.obs().counter("cache.hit.trace"), 0);
    }
}
