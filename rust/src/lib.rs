//! # flopt — automatic FPGA offloading of application loop statements
//!
//! Reproduction of Yamato, *"Evaluation of Automatic FPGA Offloading for
//! Loop Statements of Applications"* (2020).  Given unmodified C-subset
//! application source, the coordinator finds the loop statements worth
//! offloading to an FPGA, generates OpenCL for them, and searches for the
//! fastest offload pattern while keeping the number of (simulated,
//! hours-long) full FPGA compiles tiny.
//!
//! The crate is the **L3 Rust coordinator** of a three-layer stack:
//!
//! * L1 — Pallas kernels (`python/compile/kernels/`), the "FPGA bitstream"
//!   equivalents of the two paper workloads (tdfir, MRI-Q), AOT-lowered to
//!   HLO text.
//! * L2 — JAX whole-app graphs (`python/compile/model.py`).
//! * L3 — this crate: parsing, profiling, narrowing, OpenCL generation,
//!   HLS pre-compile simulation, the Arria10 board model, and the
//!   verification-environment search.  Offloaded-loop numerics execute
//!   through the PJRT runtime ([`runtime`]) against the L1 artifacts.
//!
//! See `DESIGN.md` for the full system inventory and the paper→module map.

#![warn(missing_docs)]

pub mod analyze;
pub mod apps;
pub mod backend;
pub mod baselines;
pub mod benchcmp;
pub mod cache;
pub mod config;
pub mod coordinator;
pub mod cparse;
pub mod cpu;
pub mod fleet;
pub mod fpga;
pub mod funcblock;
pub mod hls;
pub mod intensity;
pub mod interp;
pub mod ir;
pub mod metrics;
pub mod obs;
pub mod opencl;
pub mod runtime;
pub mod serve;
pub mod service;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
