//! Multi-tenant FPGA fleet placement: many applications sharing a
//! bounded pool of Arria10 boards.
//!
//! The paper's coordinator offloads **one** app onto **one** board.  The
//! production story (ROADMAP: heavy multi-user traffic) is N tenants
//! contending for M boards: each app's offload search still produces a
//! per-app winner (the `best` loop pattern / `best_block` IP placement
//! on its [`SearchTrace`]), but *which* winners actually get silicon is
//! now a fleet-level decision.  This subsystem adds that layer on top of
//! the PR-3 batch service:
//!
//! 1. **Demand extraction** ([`tenant_from_trace`]) — each app's trace
//!    becomes a [`TenantDemand`] carrying up to two placement options in
//!    preference order: the trace's overall solution first, the other
//!    side (loop pattern ⇄ block placement) as the under-pressure
//!    fallback.  Loop patterns carry their true per-type FF/LUT/DSP/BRAM
//!    vectors (summed HLS reports); IP placements carry a demand vector
//!    reproducing their measured utilization.  Degenerate (NaN-poisoned)
//!    or non-improving measurements are rejected here — a poisoned
//!    tenant stays on the CPU, it can never panic the scheduler.
//! 2. **Packing** ([`pack::first_fit_decreasing`]) — a deterministic
//!    first-fit-decreasing packer co-schedules demands onto boards under
//!    the per-board resource cap, falling back to a tenant's alternate
//!    option when its preferred one no longer fits anywhere.  A board
//!    that already hosts a tenant must swap bitstreams to take another:
//!    the incoming tenant is charged its reconfiguration cost — a full
//!    PnR-scale rebuild for generated patterns, a minutes-scale
//!    partial-reconfiguration link for prebuilt registry IP — which is
//!    why IP blocks win placements under pressure.
//! 3. **Admission** — tenants that fit nowhere are *queued* (they would
//!    fit on an empty board) or *rejected* (they can never fit under the
//!    cap); both fall back to the all-CPU baseline, so the fleet's
//!    aggregate speedup never loses to running every app on the CPU.
//! 4. **Reporting** ([`report::FleetReport`]) — per-app placements,
//!    per-board utilization, and the aggregate speedup, with canonical
//!    (artifact-derived) automation hours so the cached report is
//!    byte-identical across warm re-runs and pool sizes.
//!
//! Exposed as `flopt fleet --boards N`; placement reports are cached
//! like every other stage artifact ([`crate::cache::fleet_key`]).

pub mod pack;
pub mod report;

pub use pack::{
    first_fit_decreasing, incremental_repack, BoardState, PackOutcome, Placement, RepackOutcome,
};
pub use report::{AppPlacement, BoardReport, FleetReport, FleetStatus};

use std::sync::Arc;

use crate::apps::App;
use crate::backend::{Target, FPGA};
use crate::cache;
use crate::config::SearchConfig;
use crate::coordinator::pipeline::{offload_search, SearchTrace};
use crate::coordinator::verify_env::{PatternMeasurement, VerifyEnv};
use crate::fpga::device::{Device, Resources};
use crate::funcblock::BlockMeasurement;
use crate::service::{BatchRequest, BatchService};

/// How a placement option reaches the board.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementKind {
    /// A generated OpenCL pattern: swapping it onto a board is a full
    /// place-and-route-scale reconfiguration (hours).
    Bitstream,
    /// A prebuilt registry IP core alone: swapping it in is a partial-
    /// reconfiguration link (minutes).
    IpLink,
}

impl PlacementKind {
    /// Report label ("bitstream" / "ip-link").
    pub fn as_str(self) -> &'static str {
        match self {
            PlacementKind::Bitstream => "bitstream",
            PlacementKind::IpLink => "ip-link",
        }
    }
}

/// One way a tenant could run on a board: a measured winner with its
/// resource demand and the cost of swapping it onto occupied silicon.
#[derive(Debug, Clone)]
pub struct PlacementOption {
    /// Human-readable solution label (`pattern L8+L9`, `block fir...`).
    pub label: String,
    /// Bitstream vs. cheap IP link (drives the reconfiguration cost).
    pub kind: PlacementKind,
    /// Measured device fraction (incl. the BSP static region).
    pub utilization: f64,
    /// Per-type resource demand of the dynamic region.
    pub resources: Resources,
    /// Measured wall-clock of the sample app under this placement.
    pub time_s: f64,
    /// Measured speedup vs. all-CPU.
    pub speedup: f64,
    /// Simulated seconds to swap this image onto an occupied board.
    pub reconfig_s: f64,
}

impl PlacementOption {
    /// Can the packer admit this option at all (finite numbers, a real
    /// win over the CPU)?  The same rule [`tenant_from_trace`] applies
    /// at extraction — one predicate, so the two can never diverge.
    pub fn is_schedulable(&self) -> bool {
        measurement_is_sane(self.utilization, self.time_s, self.speedup)
    }
}

/// One tenant's demand on the fleet: its app identity, its all-CPU
/// fallback, and its placement options in preference order.
#[derive(Debug, Clone)]
pub struct TenantDemand {
    /// Registry name of the tenant app.
    pub app_name: String,
    /// Submission order (the deterministic tie-break of last resort).
    pub order: usize,
    /// All-CPU baseline of the sample run (the admission fallback).
    pub cpu_time_s: f64,
    /// Placement options, preferred first (empty: the app stays on CPU).
    pub options: Vec<PlacementOption>,
}

/// Extract a tenant demand from an app's completed search trace.
///
/// The trace's overall solution leads the option list; the other side
/// (loop-pattern ⇄ block) rides second as the under-pressure fallback.
/// Measurements that did not compile, did not improve on the CPU, or
/// carry non-finite numbers (a NaN-poisoned run) yield no option.
pub fn tenant_from_trace(t: &SearchTrace, device: &Device, order: usize) -> TenantDemand {
    let loop_opt = t.best.as_ref().and_then(|m| loop_option(t, m, device));
    let block_opt = t.best_block.as_ref().and_then(|m| block_option(m, device));
    let mut options = Vec::new();
    if t.solution_is_block() {
        options.extend(block_opt);
        options.extend(loop_opt);
    } else {
        options.extend(loop_opt);
        options.extend(block_opt);
    }
    TenantDemand {
        app_name: t.app_name.clone(),
        order,
        cpu_time_s: t.cpu_time_s,
        options,
    }
}

/// Is a measured (utilization, time, speedup) triple sane enough to
/// schedule?  NaN/∞ anywhere rejects the placement outright.
fn measurement_is_sane(utilization: f64, time_s: f64, speedup: f64) -> bool {
    utilization.is_finite() && time_s.is_finite() && speedup.is_finite() && speedup > 1.0
}

fn loop_option(
    t: &SearchTrace,
    m: &PatternMeasurement,
    device: &Device,
) -> Option<PlacementOption> {
    if !m.compiled || !measurement_is_sane(m.utilization, m.time_s, m.speedup) {
        return None;
    }
    // true per-type demand: the sum of the pattern loops' HLS vectors
    let mut res = Resources::ZERO;
    let mut have_all = true;
    for l in &m.pattern.loops {
        match t
            .candidates
            .iter()
            .find(|c| c.id == *l)
            .and_then(|c| c.report.resources())
        {
            Some(r) => res = res.add(r),
            None => {
                have_all = false;
                break;
            }
        }
    }
    if !have_all {
        // no per-type vector (non-FPGA report): synthesize a uniform
        // demand reproducing the measured utilization
        res = device.total.scale((m.utilization - device.bsp_frac).max(0.0));
    }
    Some(PlacementOption {
        label: format!("pattern {}", m.pattern.label()),
        kind: PlacementKind::Bitstream,
        utilization: m.utilization,
        resources: res,
        time_s: m.time_s,
        speedup: m.speedup,
        reconfig_s: m.compile_sim_s,
    })
}

fn block_option(m: &BlockMeasurement, device: &Device) -> Option<PlacementOption> {
    if !m.compiled || !measurement_is_sane(m.utilization, m.time_s, m.speedup) {
        return None;
    }
    let res = device.total.scale((m.utilization - device.bsp_frac).max(0.0));
    Some(PlacementOption {
        label: format!("block {}", m.label()),
        kind: if m.is_pure_ip() {
            PlacementKind::IpLink
        } else {
            PlacementKind::Bitstream
        },
        utilization: m.utilization,
        resources: res,
        time_s: m.time_s,
        speedup: m.speedup,
        reconfig_s: m.compile_sim_s,
    })
}

/// Run the full fleet flow on a batch service: per-app FPGA searches
/// (analyze-once, cache-deduped, merged onto the service's one shared
/// clock), demand extraction, deterministic packing onto `boards`
/// Arria10 boards, reconfiguration accounting, and the cached report.
///
/// A warm fleet-report cache hit returns the stored report bit-
/// identically without running anything.
pub fn fleet_search(
    service: &BatchService,
    apps: &[&'static App],
    boards: usize,
    cfg: &SearchConfig,
    test_scale: bool,
) -> crate::Result<FleetReport> {
    let boards = boards.max(1);
    let backend = &FPGA;
    let key = cache::fleet_key(apps, test_scale, backend, cfg, boards);
    if let Some(r) = service.cache().get_fleet(key) {
        crate::coordinator::pipeline::cache_hit(service.clock(), "cache.hit.fleet");
        return Ok(r);
    }
    service.clock().obs().count("cache.miss.fleet", 1);

    // per-app winners through the batch service (shared clock + cache).
    // The service's store is always live — `BatchService::new` creates a
    // fresh one and `with_cache` upgrades a disabled (`--no-cache`)
    // store — so the traces `run` publishes are reachable below; the
    // `get_trace` fallback only fires for foreign/partial disk stores.
    let requests: Vec<BatchRequest> = apps
        .iter()
        .map(|app| BatchRequest {
            app: *app,
            target: Target::Fpga,
            cfg: cfg.clone(),
            test_scale,
        })
        .collect();
    service.run(&requests)?;

    let mut traces: Vec<SearchTrace> = Vec::with_capacity(apps.len());
    for app in apps {
        let tkey = cache::trace_key(app, test_scale, backend, cfg);
        let t = match service.cache().get_trace(tkey) {
            Some(t) => t,
            None => {
                // destination outcome was warm but its trace is not in
                // this store: run the trace-level search against the
                // same shared cache + clock (warm stages make it cheap)
                let env = VerifyEnv::with_clock(
                    backend,
                    service.cpu(),
                    cfg.clone(),
                    Arc::clone(service.clock()),
                )
                .with_cache(Arc::clone(service.cache()));
                offload_search(app, &env, test_scale)?
            }
        };
        traces.push(t);
    }

    let device = backend.device;
    let demands: Vec<TenantDemand> = traces
        .iter()
        .enumerate()
        .map(|(i, t)| tenant_from_trace(t, device, i))
        .collect();
    let pack_span = service.clock().span("fleet.pack", "fleet");
    let outcome = pack::first_fit_decreasing(&demands, boards, cfg.resource_cap, device);
    service.clock().span_end(pack_span);

    // every bitstream swap is real compile-farm work on the shared clock
    let mut reconfigs: u64 = 0;
    for (di, p) in outcome.placements.iter().enumerate() {
        if let Placement::Placed { reconfig_s, .. } = p {
            if *reconfig_s > 0.0 {
                reconfigs += 1;
                service.clock().schedule_compile(
                    &format!("reconfig {}", demands[di].app_name),
                    *reconfig_s,
                );
            }
        }
    }
    {
        let obs = service.clock().obs();
        obs.count("fleet.tenants", demands.len() as u64);
        let placed = outcome
            .placements
            .iter()
            .filter(|p| matches!(p, Placement::Placed { .. }))
            .count();
        obs.count("fleet.packed_tenants", placed as u64);
        obs.count("fleet.reconfigs", reconfigs);
    }

    // canonical automation hours: the artifact-derived cost of the
    // per-app searches plus the reconfiguration work — a pure function
    // of the traces and the packing, never of what this run reused
    let base_sim: f64 = traces.iter().map(|t| t.sim_hours).sum();
    let base_compile: f64 = traces.iter().map(|t| t.compile_hours).sum();

    let report = report::build(&demands, &outcome, boards, device, base_sim, base_compile);
    service.cache().put_fleet(key, &report);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::cpu::XEON_3104;

    #[test]
    fn poisoned_trace_yields_no_options() {
        let svc = BatchService::new(2, 1, &XEON_3104);
        let apps_list: Vec<&'static App> = vec![&apps::MATMUL];
        fleet_search(&svc, &apps_list, 1, &SearchConfig::default(), true).unwrap();
        let tkey = cache::trace_key(&apps::MATMUL, true, &FPGA, &SearchConfig::default());
        let mut t = svc.cache().get_trace(tkey).expect("trace cached");
        // poison the winner: the tenant must degrade to CPU, not panic
        if let Some(best) = &mut t.best {
            best.speedup = f64::NAN;
            best.time_s = f64::NAN;
        }
        let d = tenant_from_trace(&t, FPGA.device, 0);
        assert!(
            d.options.is_empty(),
            "a NaN-poisoned winner must be rejected: {:?}",
            d.options
        );
    }

    #[test]
    fn loop_options_carry_true_resource_vectors() {
        let svc = BatchService::new(2, 1, &XEON_3104);
        let apps_list: Vec<&'static App> = vec![&apps::TDFIR];
        fleet_search(&svc, &apps_list, 1, &SearchConfig::default(), true).unwrap();
        let tkey = cache::trace_key(&apps::TDFIR, true, &FPGA, &SearchConfig::default());
        let t = svc.cache().get_trace(tkey).expect("trace cached");
        let d = tenant_from_trace(&t, FPGA.device, 0);
        assert!(!d.options.is_empty(), "tdfir has a winning pattern");
        let opt = &d.options[0];
        assert!(opt.resources.alms > 0.0, "per-type demand must be real");
        // the vector must reproduce the measured utilization rule
        let util = FPGA.device.utilization(&opt.resources);
        assert!(util <= opt.utilization + 1e-9, "vector util {util} vs {}", opt.utilization);
        assert!(opt.speedup > 1.0);
    }
}
