//! The deterministic first-fit-decreasing fleet packer.
//!
//! Classic FFD bin packing adapted to FPGA boards: tenants are placed in
//! decreasing order of their preferred option's device utilization (the
//! hardest-to-place demand goes first), each onto the **first** board
//! where its combined per-type resources stay under the cap.  Two
//! fleet-specific twists:
//!
//! * **Option fallback** — when a tenant's preferred placement (usually
//!   its fastest) no longer fits on any board, its alternate placement
//!   (the other side of the loop-pattern ⇄ IP-block search) is tried
//!   before the tenant is turned away.  Under pressure this is exactly
//!   where prebuilt IP blocks win: they are the cheap-to-link fallback.
//! * **Reconfiguration accounting** — a board that already hosts a
//!   tenant must swap bitstreams to admit another, so every placement
//!   after a board's first charges the incoming option's
//!   reconfiguration cost (hours for generated patterns, minutes for a
//!   prebuilt-IP partial-reconfiguration link).
//!
//! Ordering uses the NaN-safe total-order comparators of
//! [`crate::util::order`] with deterministic tie-breaks (cheaper
//! reconfiguration first, then submission order), so the packing — and
//! therefore the whole fleet report — is a pure function of the demand
//! set: byte-identical across runs, pool sizes, and platforms.

use crate::fpga::device::{Device, Resources};
use crate::util::order;

use super::TenantDemand;

/// One board's packing state.
#[derive(Debug, Clone)]
pub struct BoardState {
    /// Summed per-type resource demand of everything placed here.
    pub used: Resources,
    /// Demand indices placed on this board, in placement order.
    pub tenants: Vec<usize>,
}

/// Where one tenant landed.
#[derive(Debug, Clone, PartialEq)]
pub enum Placement {
    /// Placed on `board` using `option` (index into the demand's option
    /// list), paying `reconfig_s` of bitstream-swap time if the board
    /// was already occupied.
    Placed {
        /// Board index in `0..boards`.
        board: usize,
        /// Index of the chosen option in the tenant's option list.
        option: usize,
        /// Simulated reconfiguration seconds charged on admission.
        reconfig_s: f64,
    },
    /// Admission deferred: some option fits an *empty* board, but every
    /// board is currently too full.  The tenant runs on the CPU.
    Queued,
    /// Admission rejected: no option can ever fit under the cap.  The
    /// tenant runs on the CPU.
    Rejected,
    /// The tenant had no improving placement option at all (its search
    /// found nothing better than the CPU, or its measurements were
    /// poisoned): it stays on the CPU by construction.
    StayCpu,
}

/// The packer's result.
#[derive(Debug, Clone)]
pub struct PackOutcome {
    /// Per-board state, indexed by board id.
    pub boards: Vec<BoardState>,
    /// Per-demand placement, indexed like the input demand slice.
    pub placements: Vec<Placement>,
}

/// Deterministic first-fit-decreasing packing of `demands` onto
/// `boards` boards of `device`, under a combined per-board utilization
/// cap (the same `resource_cap` the pattern search enforces).
pub fn first_fit_decreasing(
    demands: &[TenantDemand],
    boards: usize,
    cap: f64,
    device: &Device,
) -> PackOutcome {
    let boards = boards.max(1);
    let mut state: Vec<BoardState> = (0..boards)
        .map(|_| BoardState { used: Resources::ZERO, tenants: Vec::new() })
        .collect();
    let mut placements: Vec<Placement> = demands
        .iter()
        .map(|d| {
            if d.options.iter().any(|o| o.is_schedulable()) {
                Placement::Queued // provisional; resolved below
            } else {
                Placement::StayCpu
            }
        })
        .collect();

    // FFD order: hardest demand first; ties go to the cheaper-to-link
    // tenant, then to submission order — a total, deterministic order.
    let mut idx: Vec<usize> = (0..demands.len())
        .filter(|&i| placements[i] == Placement::Queued)
        .collect();
    idx.sort_by(|&a, &b| {
        let (da, db) = (&demands[a], &demands[b]);
        order::desc_nan_last(da.options[0].utilization, db.options[0].utilization)
            .then_with(|| {
                order::asc_nan_last(da.options[0].reconfig_s, db.options[0].reconfig_s)
            })
            .then_with(|| da.order.cmp(&db.order))
    });

    for &di in &idx {
        let d = &demands[di];
        let mut placed = false;
        'options: for (oi, opt) in d.options.iter().enumerate() {
            if !opt.is_schedulable() {
                continue;
            }
            for (bi, b) in state.iter_mut().enumerate() {
                let combined = b.used.add(&opt.resources);
                if device.utilization(&combined) <= cap {
                    // admitting onto occupied silicon swaps bitstreams:
                    // the incoming tenant pays its reconfiguration cost
                    let reconfig_s = if b.tenants.is_empty() { 0.0 } else { opt.reconfig_s };
                    b.used = combined;
                    b.tenants.push(di);
                    placements[di] = Placement::Placed { board: bi, option: oi, reconfig_s };
                    placed = true;
                    break 'options;
                }
            }
        }
        if !placed {
            let feasible_alone = d
                .options
                .iter()
                .filter(|o| o.is_schedulable())
                .any(|o| device.utilization(&o.resources) <= cap);
            placements[di] = if feasible_alone { Placement::Queued } else { Placement::Rejected };
        }
    }

    PackOutcome { boards: state, placements }
}

#[cfg(test)]
mod tests {
    use super::super::{PlacementKind, PlacementOption, TenantDemand};
    use super::*;
    use crate::fpga::ARRIA10_GX;

    fn opt(frac: f64, speedup: f64, reconfig_s: f64, kind: PlacementKind) -> PlacementOption {
        PlacementOption {
            label: format!("probe {frac:.2}"),
            kind,
            utilization: ARRIA10_GX.bsp_frac + frac,
            resources: ARRIA10_GX.total.scale(frac),
            time_s: 1.0 / speedup,
            speedup,
            reconfig_s,
        }
    }

    fn tenant(name: &str, order: usize, options: Vec<PlacementOption>) -> TenantDemand {
        TenantDemand {
            app_name: name.to_string(),
            order,
            cpu_time_s: 1.0,
            options,
        }
    }

    #[test]
    fn respects_the_per_board_cap() {
        // cap 0.85 with bsp 0.18 leaves 0.67 of dynamic fraction/board:
        // two 0.4-fraction tenants must land on different boards
        let demands = vec![
            tenant("a", 0, vec![opt(0.4, 3.0, 3600.0, PlacementKind::Bitstream)]),
            tenant("b", 1, vec![opt(0.4, 2.0, 3600.0, PlacementKind::Bitstream)]),
        ];
        let out = first_fit_decreasing(&demands, 2, 0.85, &ARRIA10_GX);
        let boards: Vec<usize> = out
            .placements
            .iter()
            .map(|p| match p {
                Placement::Placed { board, .. } => *board,
                other => panic!("both must place: {other:?}"),
            })
            .collect();
        assert_ne!(boards[0], boards[1], "0.4+0.4 dynamic would blow the cap");
        for b in &out.boards {
            assert!(ARRIA10_GX.utilization(&b.used) <= 0.85);
        }
    }

    #[test]
    fn second_tenant_on_a_board_pays_reconfiguration() {
        let demands = vec![
            tenant("a", 0, vec![opt(0.2, 3.0, 3.0 * 3600.0, PlacementKind::Bitstream)]),
            tenant("b", 1, vec![opt(0.2, 2.0, 3.0 * 3600.0, PlacementKind::Bitstream)]),
        ];
        let out = first_fit_decreasing(&demands, 1, 0.85, &ARRIA10_GX);
        let costs: Vec<f64> = out
            .placements
            .iter()
            .map(|p| match p {
                Placement::Placed { reconfig_s, .. } => *reconfig_s,
                other => panic!("both must place: {other:?}"),
            })
            .collect();
        assert_eq!(costs.iter().filter(|c| **c == 0.0).count(), 1, "first is free");
        assert_eq!(
            costs.iter().filter(|c| **c == 3.0 * 3600.0).count(),
            1,
            "second pays the swap"
        );
    }

    #[test]
    fn under_pressure_the_ip_fallback_wins_the_slot() {
        // `big` (0.5 dynamic) packs first and holds the only board; the
        // preferred 0.45 bitstream of `flex` no longer fits anywhere,
        // but its cheap 0.15 IP fallback does — and links in minutes
        let demands = vec![
            tenant("big", 0, vec![opt(0.5, 4.0, 3.0 * 3600.0, PlacementKind::Bitstream)]),
            tenant(
                "flex",
                1,
                vec![
                    opt(0.45, 3.5, 3.0 * 3600.0, PlacementKind::Bitstream),
                    opt(0.15, 2.0, 420.0, PlacementKind::IpLink),
                ],
            ),
        ];
        let out = first_fit_decreasing(&demands, 1, 0.85, &ARRIA10_GX);
        assert!(matches!(out.placements[0], Placement::Placed { option: 0, .. }));
        match &out.placements[1] {
            Placement::Placed { option, reconfig_s, .. } => {
                assert_eq!(*option, 1, "the IP fallback must win the contended slot");
                assert_eq!(*reconfig_s, 420.0, "and it links cheaply");
            }
            other => panic!("flex must place via its fallback: {other:?}"),
        }
    }

    #[test]
    fn queued_vs_rejected_vs_stay_cpu() {
        let demands = vec![
            tenant("hog", 0, vec![opt(0.6, 5.0, 3600.0, PlacementKind::Bitstream)]),
            // fits an empty board, but the hog holds the only one
            tenant("waits", 1, vec![opt(0.5, 2.0, 3600.0, PlacementKind::Bitstream)]),
            // can never fit under the cap at all
            tenant("never", 2, vec![opt(0.9, 9.0, 3600.0, PlacementKind::Bitstream)]),
            // nothing improving to place
            tenant("cpu", 3, vec![]),
            // poisoned measurement: rejected outright, no panic
            tenant("nan", 4, vec![opt(f64::NAN, f64::NAN, 3600.0, PlacementKind::Bitstream)]),
        ];
        let out = first_fit_decreasing(&demands, 1, 0.85, &ARRIA10_GX);
        assert!(matches!(out.placements[0], Placement::Placed { .. }));
        assert_eq!(out.placements[1], Placement::Queued);
        assert_eq!(out.placements[2], Placement::Rejected);
        assert_eq!(out.placements[3], Placement::StayCpu);
        assert_eq!(out.placements[4], Placement::StayCpu);
    }

    #[test]
    fn packing_is_deterministic_for_any_input_order() {
        let a = tenant("a", 0, vec![opt(0.3, 3.0, 3600.0, PlacementKind::Bitstream)]);
        let b = tenant("b", 1, vec![opt(0.3, 2.0, 420.0, PlacementKind::IpLink)]);
        let c = tenant("c", 2, vec![opt(0.5, 4.0, 3600.0, PlacementKind::Bitstream)]);
        // the pack sequence is a function of (utilization, reconfig,
        // submission order) — never of the slice order handed in
        let packed_apps = |demands: &[TenantDemand]| -> Vec<String> {
            let out = first_fit_decreasing(demands, 2, 0.85, &ARRIA10_GX);
            out.boards
                .iter()
                .flat_map(|bd| bd.tenants.iter().map(|&i| demands[i].app_name.clone()))
                .collect()
        };
        let fwd = packed_apps(&[a.clone(), b.clone(), c.clone()]);
        let rev = packed_apps(&[c, b, a]);
        assert_eq!(fwd, rev, "packing must not depend on slice order");
        assert_eq!(fwd[0], "c", "the 0.5 demand packs first (FFD)");
        assert_eq!(fwd[1], "b", "tie at 0.3 goes to the cheap IP link");
    }
}
