//! The deterministic first-fit-decreasing fleet packer.
//!
//! Classic FFD bin packing adapted to FPGA boards: tenants are placed in
//! decreasing order of their preferred option's device utilization (the
//! hardest-to-place demand goes first), each onto the **first** board
//! where its combined per-type resources stay under the cap.  Two
//! fleet-specific twists:
//!
//! * **Option fallback** — when a tenant's preferred placement (usually
//!   its fastest) no longer fits on any board, its alternate placement
//!   (the other side of the loop-pattern ⇄ IP-block search) is tried
//!   before the tenant is turned away.  Under pressure this is exactly
//!   where prebuilt IP blocks win: they are the cheap-to-link fallback.
//! * **Reconfiguration accounting** — a board that already hosts a
//!   tenant must swap bitstreams to admit another, so every placement
//!   after a board's first charges the incoming option's
//!   reconfiguration cost (hours for generated patterns, minutes for a
//!   prebuilt-IP partial-reconfiguration link).
//!
//! Ordering uses the NaN-safe total-order comparators of
//! [`crate::util::order`] with deterministic tie-breaks (cheaper
//! reconfiguration first, then submission order), so the packing — and
//! therefore the whole fleet report — is a pure function of the demand
//! set: byte-identical across runs, pool sizes, and platforms.

use crate::fpga::device::{Device, Resources};
use crate::util::order;

use super::TenantDemand;

/// One board's packing state.
#[derive(Debug, Clone)]
pub struct BoardState {
    /// Summed per-type resource demand of everything placed here.
    pub used: Resources,
    /// Demand indices placed on this board, in placement order.
    pub tenants: Vec<usize>,
}

/// Where one tenant landed.
#[derive(Debug, Clone, PartialEq)]
pub enum Placement {
    /// Placed on `board` using `option` (index into the demand's option
    /// list), paying `reconfig_s` of bitstream-swap time if the board
    /// was already occupied.
    Placed {
        /// Board index in `0..boards`.
        board: usize,
        /// Index of the chosen option in the tenant's option list.
        option: usize,
        /// Simulated reconfiguration seconds charged on admission.
        reconfig_s: f64,
    },
    /// Admission deferred: some option fits an *empty* board, but every
    /// board is currently too full.  The tenant runs on the CPU.
    Queued,
    /// Admission rejected: no option can ever fit under the cap.  The
    /// tenant runs on the CPU.
    Rejected,
    /// The tenant had no improving placement option at all (its search
    /// found nothing better than the CPU, or its measurements were
    /// poisoned): it stays on the CPU by construction.
    StayCpu,
}

/// The packer's result.
#[derive(Debug, Clone)]
pub struct PackOutcome {
    /// Per-board state, indexed by board id.
    pub boards: Vec<BoardState>,
    /// Per-demand placement, indexed like the input demand slice.
    pub placements: Vec<Placement>,
}

/// The incremental packer's result ([`incremental_repack`]).
#[derive(Debug, Clone)]
pub struct RepackOutcome {
    /// The adopted packing (incremental or escalated full re-pack).
    pub outcome: PackOutcome,
    /// Tenants whose `(board, option)` changed from a prior placement —
    /// each one is a live migration paying a bitstream swap.
    pub migrations: usize,
    /// Summed reconfiguration seconds charged to those migrations.
    pub migration_s: f64,
    /// Did the packer escalate to a full FFD re-pack?  Only happens
    /// when the full pack places strictly more tenants.
    pub full: bool,
}

/// Provisional placement vector: schedulable demands start `Queued`,
/// hopeless ones `StayCpu`.
fn provisional(demands: &[TenantDemand]) -> Vec<Placement> {
    demands
        .iter()
        .map(|d| {
            if d.options.iter().any(|o| o.is_schedulable()) {
                Placement::Queued // provisional; resolved by the packer
            } else {
                Placement::StayCpu
            }
        })
        .collect()
}

/// FFD order: hardest demand first; ties go to the cheaper-to-link
/// tenant, then to submission order — a total, deterministic order.
fn ffd_sort(idx: &mut [usize], demands: &[TenantDemand]) {
    idx.sort_by(|&a, &b| {
        let (da, db) = (&demands[a], &demands[b]);
        order::desc_nan_last(da.options[0].utilization, db.options[0].utilization)
            .then_with(|| {
                order::asc_nan_last(da.options[0].reconfig_s, db.options[0].reconfig_s)
            })
            .then_with(|| da.order.cmp(&db.order))
    });
}

/// First-fit one demand onto the current board state, trying its
/// options in preference order.  Returns the placement or `None`.
fn place_first_fit(
    di: usize,
    d: &TenantDemand,
    state: &mut [BoardState],
    cap: f64,
    device: &Device,
) -> Option<Placement> {
    for (oi, opt) in d.options.iter().enumerate() {
        if !opt.is_schedulable() {
            continue;
        }
        for (bi, b) in state.iter_mut().enumerate() {
            let combined = b.used.add(&opt.resources);
            if device.utilization(&combined) <= cap {
                // admitting onto occupied silicon swaps bitstreams:
                // the incoming tenant pays its reconfiguration cost
                let reconfig_s = if b.tenants.is_empty() { 0.0 } else { opt.reconfig_s };
                b.used = combined;
                b.tenants.push(di);
                return Some(Placement::Placed { board: bi, option: oi, reconfig_s });
            }
        }
    }
    None
}

/// An unplaced schedulable demand is `Queued` if some option could fit
/// an empty board, `Rejected` if nothing can ever fit under the cap.
fn resolve_unplaced(d: &TenantDemand, cap: f64, device: &Device) -> Placement {
    let feasible_alone = d
        .options
        .iter()
        .filter(|o| o.is_schedulable())
        .any(|o| device.utilization(&o.resources) <= cap);
    if feasible_alone {
        Placement::Queued
    } else {
        Placement::Rejected
    }
}

/// Deterministic first-fit-decreasing packing of `demands` onto
/// `boards` boards of `device`, under a combined per-board utilization
/// cap (the same `resource_cap` the pattern search enforces).
pub fn first_fit_decreasing(
    demands: &[TenantDemand],
    boards: usize,
    cap: f64,
    device: &Device,
) -> PackOutcome {
    let boards = boards.max(1);
    let mut state: Vec<BoardState> = (0..boards)
        .map(|_| BoardState { used: Resources::ZERO, tenants: Vec::new() })
        .collect();
    let mut placements = provisional(demands);

    let mut idx: Vec<usize> = (0..demands.len())
        .filter(|&i| placements[i] == Placement::Queued)
        .collect();
    ffd_sort(&mut idx, demands);

    for &di in &idx {
        let d = &demands[di];
        placements[di] = place_first_fit(di, d, &mut state, cap, device)
            .unwrap_or_else(|| resolve_unplaced(d, cap, device));
    }

    PackOutcome { boards: state, placements }
}

fn placed_count(outcome: &PackOutcome) -> usize {
    outcome
        .placements
        .iter()
        .filter(|p| matches!(p, Placement::Placed { .. }))
        .count()
}

/// Settle reconfiguration charges against the prior placements and
/// count live migrations: a tenant keeping its exact `(board, option)`
/// pays nothing (the bitstream is already resident), a tenant moved
/// away from a prior placement pays its option's full swap cost, and a
/// fresh admission keeps the charge the packer assessed.
fn settle_migrations(
    demands: &[TenantDemand],
    previous: &[Option<(usize, usize)>],
    placements: &mut [Placement],
) -> (usize, f64) {
    let mut migrations = 0;
    let mut migration_s = 0.0;
    for (i, p) in placements.iter_mut().enumerate() {
        if let Placement::Placed { board, option, reconfig_s } = p {
            match previous.get(i).copied().flatten() {
                Some((pb, po)) if pb == *board && po == *option => *reconfig_s = 0.0,
                Some(_) => {
                    let cost = demands[i].options[*option].reconfig_s;
                    *reconfig_s = cost;
                    migrations += 1;
                    migration_s += cost;
                }
                None => {}
            }
        }
    }
    (migrations, migration_s)
}

/// Incremental re-pack for a live fleet: tenants already placed keep
/// their board and option at zero cost whenever they still fit, and
/// only joiners (or tenants displaced by a board-count or cap change)
/// run first-fit into the residual capacity.  If anyone schedulable is
/// still waiting afterwards, the packer computes a full
/// [`first_fit_decreasing`] pack and adopts it **only** when it places
/// strictly more tenants — churn is never paid for nothing.  Every
/// adopted move away from a prior placement is a live migration
/// charged its option's bitstream-swap cost.
///
/// `previous[i]` is demand `i`'s prior `(board, option)`, `None` for a
/// joiner.  Like the full packer, the result is a pure function of its
/// inputs — byte-identical across runs and pool sizes.
pub fn incremental_repack(
    demands: &[TenantDemand],
    previous: &[Option<(usize, usize)>],
    boards: usize,
    cap: f64,
    device: &Device,
) -> RepackOutcome {
    let boards = boards.max(1);
    let mut state: Vec<BoardState> = (0..boards)
        .map(|_| BoardState { used: Resources::ZERO, tenants: Vec::new() })
        .collect();
    let mut placements = provisional(demands);

    // Phase 1 — keepers hold their boards, in submission order.
    for i in 0..demands.len() {
        if placements[i] != Placement::Queued {
            continue;
        }
        let Some((pb, po)) = previous.get(i).copied().flatten() else { continue };
        if pb >= boards {
            continue; // the fleet shrank under this tenant
        }
        let d = &demands[i];
        let Some(opt) = d.options.get(po) else { continue };
        if !opt.is_schedulable() {
            continue;
        }
        let combined = state[pb].used.add(&opt.resources);
        if device.utilization(&combined) <= cap {
            state[pb].used = combined;
            state[pb].tenants.push(i);
            placements[i] = Placement::Placed { board: pb, option: po, reconfig_s: 0.0 };
        }
    }

    // Phase 2 — joiners and displaced tenants first-fit the residual.
    let mut idx: Vec<usize> = (0..demands.len())
        .filter(|&i| placements[i] == Placement::Queued)
        .collect();
    ffd_sort(&mut idx, demands);
    for &di in &idx {
        let d = &demands[di];
        placements[di] = place_first_fit(di, d, &mut state, cap, device)
            .unwrap_or_else(|| resolve_unplaced(d, cap, device));
    }

    let incremental = PackOutcome { boards: state, placements };

    // Phase 3 — escalate only when a full re-pack places strictly more.
    let waiting = incremental.placements.iter().any(|p| *p == Placement::Queued);
    let (mut outcome, full) = if waiting {
        let full_pack = first_fit_decreasing(demands, boards, cap, device);
        if placed_count(&full_pack) > placed_count(&incremental) {
            (full_pack, true)
        } else {
            (incremental, false)
        }
    } else {
        (incremental, false)
    };

    let (migrations, migration_s) =
        settle_migrations(demands, previous, &mut outcome.placements);
    RepackOutcome { outcome, migrations, migration_s, full }
}

#[cfg(test)]
mod tests {
    use super::super::{PlacementKind, PlacementOption, TenantDemand};
    use super::*;
    use crate::fpga::ARRIA10_GX;

    fn opt(frac: f64, speedup: f64, reconfig_s: f64, kind: PlacementKind) -> PlacementOption {
        PlacementOption {
            label: format!("probe {frac:.2}"),
            kind,
            utilization: ARRIA10_GX.bsp_frac + frac,
            resources: ARRIA10_GX.total.scale(frac),
            time_s: 1.0 / speedup,
            speedup,
            reconfig_s,
        }
    }

    fn tenant(name: &str, order: usize, options: Vec<PlacementOption>) -> TenantDemand {
        TenantDemand {
            app_name: name.to_string(),
            order,
            cpu_time_s: 1.0,
            options,
        }
    }

    #[test]
    fn respects_the_per_board_cap() {
        // cap 0.85 with bsp 0.18 leaves 0.67 of dynamic fraction/board:
        // two 0.4-fraction tenants must land on different boards
        let demands = vec![
            tenant("a", 0, vec![opt(0.4, 3.0, 3600.0, PlacementKind::Bitstream)]),
            tenant("b", 1, vec![opt(0.4, 2.0, 3600.0, PlacementKind::Bitstream)]),
        ];
        let out = first_fit_decreasing(&demands, 2, 0.85, &ARRIA10_GX);
        let boards: Vec<usize> = out
            .placements
            .iter()
            .map(|p| match p {
                Placement::Placed { board, .. } => *board,
                other => panic!("both must place: {other:?}"),
            })
            .collect();
        assert_ne!(boards[0], boards[1], "0.4+0.4 dynamic would blow the cap");
        for b in &out.boards {
            assert!(ARRIA10_GX.utilization(&b.used) <= 0.85);
        }
    }

    #[test]
    fn second_tenant_on_a_board_pays_reconfiguration() {
        let demands = vec![
            tenant("a", 0, vec![opt(0.2, 3.0, 3.0 * 3600.0, PlacementKind::Bitstream)]),
            tenant("b", 1, vec![opt(0.2, 2.0, 3.0 * 3600.0, PlacementKind::Bitstream)]),
        ];
        let out = first_fit_decreasing(&demands, 1, 0.85, &ARRIA10_GX);
        let costs: Vec<f64> = out
            .placements
            .iter()
            .map(|p| match p {
                Placement::Placed { reconfig_s, .. } => *reconfig_s,
                other => panic!("both must place: {other:?}"),
            })
            .collect();
        assert_eq!(costs.iter().filter(|c| **c == 0.0).count(), 1, "first is free");
        assert_eq!(
            costs.iter().filter(|c| **c == 3.0 * 3600.0).count(),
            1,
            "second pays the swap"
        );
    }

    #[test]
    fn under_pressure_the_ip_fallback_wins_the_slot() {
        // `big` (0.5 dynamic) packs first and holds the only board; the
        // preferred 0.45 bitstream of `flex` no longer fits anywhere,
        // but its cheap 0.15 IP fallback does — and links in minutes
        let demands = vec![
            tenant("big", 0, vec![opt(0.5, 4.0, 3.0 * 3600.0, PlacementKind::Bitstream)]),
            tenant(
                "flex",
                1,
                vec![
                    opt(0.45, 3.5, 3.0 * 3600.0, PlacementKind::Bitstream),
                    opt(0.15, 2.0, 420.0, PlacementKind::IpLink),
                ],
            ),
        ];
        let out = first_fit_decreasing(&demands, 1, 0.85, &ARRIA10_GX);
        assert!(matches!(out.placements[0], Placement::Placed { option: 0, .. }));
        match &out.placements[1] {
            Placement::Placed { option, reconfig_s, .. } => {
                assert_eq!(*option, 1, "the IP fallback must win the contended slot");
                assert_eq!(*reconfig_s, 420.0, "and it links cheaply");
            }
            other => panic!("flex must place via its fallback: {other:?}"),
        }
    }

    #[test]
    fn queued_vs_rejected_vs_stay_cpu() {
        let demands = vec![
            tenant("hog", 0, vec![opt(0.6, 5.0, 3600.0, PlacementKind::Bitstream)]),
            // fits an empty board, but the hog holds the only one
            tenant("waits", 1, vec![opt(0.5, 2.0, 3600.0, PlacementKind::Bitstream)]),
            // can never fit under the cap at all
            tenant("never", 2, vec![opt(0.9, 9.0, 3600.0, PlacementKind::Bitstream)]),
            // nothing improving to place
            tenant("cpu", 3, vec![]),
            // poisoned measurement: rejected outright, no panic
            tenant("nan", 4, vec![opt(f64::NAN, f64::NAN, 3600.0, PlacementKind::Bitstream)]),
        ];
        let out = first_fit_decreasing(&demands, 1, 0.85, &ARRIA10_GX);
        assert!(matches!(out.placements[0], Placement::Placed { .. }));
        assert_eq!(out.placements[1], Placement::Queued);
        assert_eq!(out.placements[2], Placement::Rejected);
        assert_eq!(out.placements[3], Placement::StayCpu);
        assert_eq!(out.placements[4], Placement::StayCpu);
    }

    #[test]
    fn packing_is_deterministic_for_any_input_order() {
        let a = tenant("a", 0, vec![opt(0.3, 3.0, 3600.0, PlacementKind::Bitstream)]);
        let b = tenant("b", 1, vec![opt(0.3, 2.0, 420.0, PlacementKind::IpLink)]);
        let c = tenant("c", 2, vec![opt(0.5, 4.0, 3600.0, PlacementKind::Bitstream)]);
        // the pack sequence is a function of (utilization, reconfig,
        // submission order) — never of the slice order handed in
        let packed_apps = |demands: &[TenantDemand]| -> Vec<String> {
            let out = first_fit_decreasing(demands, 2, 0.85, &ARRIA10_GX);
            out.boards
                .iter()
                .flat_map(|bd| bd.tenants.iter().map(|&i| demands[i].app_name.clone()))
                .collect()
        };
        let fwd = packed_apps(&[a.clone(), b.clone(), c.clone()]);
        let rev = packed_apps(&[c, b, a]);
        assert_eq!(fwd, rev, "packing must not depend on slice order");
        assert_eq!(fwd[0], "c", "the 0.5 demand packs first (FFD)");
        assert_eq!(fwd[1], "b", "tie at 0.3 goes to the cheap IP link");
    }

    #[test]
    fn keepers_hold_their_boards_at_zero_cost() {
        let demands = vec![
            tenant("a", 0, vec![opt(0.4, 3.0, 3.0 * 3600.0, PlacementKind::Bitstream)]),
            tenant("b", 1, vec![opt(0.4, 2.0, 3.0 * 3600.0, PlacementKind::Bitstream)]),
        ];
        let previous = vec![Some((0, 0)), Some((1, 0))];
        let out = incremental_repack(&demands, &previous, 2, 0.85, &ARRIA10_GX);
        assert!(!out.full, "nothing to escalate for");
        assert_eq!(out.migrations, 0);
        assert_eq!(out.migration_s, 0.0);
        for (i, p) in out.outcome.placements.iter().enumerate() {
            match p {
                Placement::Placed { board, option, reconfig_s } => {
                    assert_eq!((*board, *option), previous[i].unwrap(), "keeper stays put");
                    assert_eq!(*reconfig_s, 0.0, "resident bitstream is free");
                }
                other => panic!("keeper must stay placed: {other:?}"),
            }
        }
    }

    #[test]
    fn joiner_packs_into_residual_without_disturbing_keepers() {
        let demands = vec![
            tenant("keeper", 0, vec![opt(0.3, 3.0, 3.0 * 3600.0, PlacementKind::Bitstream)]),
            tenant("joiner", 1, vec![opt(0.3, 2.0, 3.0 * 3600.0, PlacementKind::Bitstream)]),
        ];
        let previous = vec![Some((0, 0)), None];
        let out = incremental_repack(&demands, &previous, 2, 0.85, &ARRIA10_GX);
        assert!(!out.full);
        assert_eq!(out.migrations, 0, "a fresh admission is not a migration");
        assert!(matches!(
            out.outcome.placements[0],
            Placement::Placed { board: 0, option: 0, reconfig_s } if reconfig_s == 0.0
        ));
        match &out.outcome.placements[1] {
            Placement::Placed { board: 0, reconfig_s, .. } => {
                assert_eq!(
                    *reconfig_s,
                    3.0 * 3600.0,
                    "joining occupied silicon pays the swap"
                );
            }
            other => panic!("joiner must first-fit board 0: {other:?}"),
        }
    }

    #[test]
    fn full_repack_adopted_only_when_it_places_strictly_more() {
        // incremental leaves the joiner queued (the keeper's 0.5
        // bitstream blocks the only board), but a full FFD re-pack
        // packs the harder 0.55 joiner first and seats the keeper on
        // its cheap IP fallback — strictly more tenants placed
        let demands = vec![
            tenant(
                "keeper",
                0,
                vec![
                    opt(0.5, 4.0, 3.0 * 3600.0, PlacementKind::Bitstream),
                    opt(0.10, 2.0, 420.0, PlacementKind::IpLink),
                ],
            ),
            tenant("joiner", 1, vec![opt(0.55, 3.0, 3.0 * 3600.0, PlacementKind::Bitstream)]),
        ];
        let previous = vec![Some((0, 0)), None];
        let out = incremental_repack(&demands, &previous, 1, 0.85, &ARRIA10_GX);
        assert!(out.full, "escalation must fire: full pack seats both");
        let placed = out
            .outcome
            .placements
            .iter()
            .filter(|p| matches!(p, Placement::Placed { .. }))
            .count();
        assert_eq!(placed, 2);
        // the keeper moved off its resident bitstream: one migration,
        // charged the adopted option's swap cost
        assert_eq!(out.migrations, 1);
        assert_eq!(out.migration_s, 420.0);
        assert!(matches!(
            out.outcome.placements[0],
            Placement::Placed { option: 1, reconfig_s, .. } if reconfig_s == 420.0
        ));
    }

    #[test]
    fn shrinking_the_fleet_migrates_the_stranded_tenant() {
        let demands =
            vec![tenant("a", 0, vec![opt(0.3, 3.0, 3.0 * 3600.0, PlacementKind::Bitstream)])];
        // previously on board 1; the fleet shrank to one board
        let previous = vec![Some((1, 0))];
        let out = incremental_repack(&demands, &previous, 1, 0.85, &ARRIA10_GX);
        assert_eq!(out.migrations, 1, "the stranded tenant migrates");
        assert_eq!(out.migration_s, 3.0 * 3600.0);
        assert!(matches!(
            out.outcome.placements[0],
            Placement::Placed { board: 0, reconfig_s, .. } if reconfig_s == 3.0 * 3600.0
        ));
    }

    #[test]
    fn useless_escalation_is_not_adopted() {
        // the joiner can never fit (0.9 alone blows the cap), so a full
        // re-pack would place no more than the incremental one: the
        // keeper must not be churned
        let demands = vec![
            tenant("keeper", 0, vec![opt(0.5, 4.0, 3.0 * 3600.0, PlacementKind::Bitstream)]),
            tenant("never", 1, vec![opt(0.9, 9.0, 3.0 * 3600.0, PlacementKind::Bitstream)]),
        ];
        let previous = vec![Some((0, 0)), None];
        let out = incremental_repack(&demands, &previous, 1, 0.85, &ARRIA10_GX);
        assert!(!out.full);
        assert_eq!(out.migrations, 0);
        assert_eq!(out.outcome.placements[1], Placement::Rejected);
    }
}
