//! The fleet-level placement report: per-app placements, per-board
//! utilization, and the aggregate speedup of the whole tenant set.
//!
//! Reports are **canonical**: every number is a pure function of the
//! demand set and the packing (the per-app searches' artifact-derived
//! automation hours plus the reconfiguration work), never of what a
//! particular run happened to reuse from the cache — so the cached
//! report, and its rendered table, are byte-identical across warm
//! re-runs and worker-pool sizes.

use crate::fpga::device::{Device, Resources};

use super::pack::{PackOutcome, Placement};
use super::TenantDemand;

/// Admission outcome of one app, as the report carries it.
#[derive(Debug, Clone, PartialEq)]
pub enum FleetStatus {
    /// Running on a fleet board.
    Placed {
        /// Board index in `0..boards`.
        board: usize,
    },
    /// Waiting for a board to free up; running on the CPU meanwhile.
    Queued,
    /// Can never fit under the per-board cap; running on the CPU.
    Rejected,
    /// No improving placement existed; the app stays on the CPU.
    Cpu,
}

impl FleetStatus {
    /// Report label ("board N" / "queued" / "rejected" / "cpu").
    pub fn label(&self) -> String {
        match self {
            FleetStatus::Placed { board } => format!("board {board}"),
            FleetStatus::Queued => "queued".to_string(),
            FleetStatus::Rejected => "rejected".to_string(),
            FleetStatus::Cpu => "cpu".to_string(),
        }
    }
}

/// One app's row of the fleet report.
#[derive(Debug, Clone, PartialEq)]
pub struct AppPlacement {
    /// Registry name of the tenant app.
    pub app_name: String,
    /// Where the app landed.
    pub status: FleetStatus,
    /// Solution label (`pattern L8+L9`, `block fir_filter[L8+L9]`, or
    /// `all-CPU` when nothing placed).
    pub solution: String,
    /// How the placement reaches the board ("bitstream" / "ip-link" /
    /// "cpu").
    pub kind: &'static str,
    /// Device fraction the placement occupies (0 when on the CPU).
    pub utilization: f64,
    /// Wall-clock of the sample app under this admission decision.
    pub time_s: f64,
    /// Speedup vs. all-CPU under this admission decision (1.0 on CPU).
    pub speedup: f64,
    /// Reconfiguration seconds charged on admission (0 for a board's
    /// first tenant and for CPU fallbacks).
    pub reconfig_s: f64,
}

/// One board's row of the fleet report.
#[derive(Debug, Clone, PartialEq)]
pub struct BoardReport {
    /// Board index.
    pub board: usize,
    /// Combined device utilization (incl. the BSP static region).
    pub utilization: f64,
    /// Summed per-type resource demand of the board's tenants.
    pub resources: Resources,
    /// Tenant app names, in placement order.
    pub tenants: Vec<String>,
}

/// The complete fleet placement report.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetReport {
    /// Number of boards in the fleet.
    pub boards: usize,
    /// Per-app rows, in submission order.
    pub apps: Vec<AppPlacement>,
    /// Per-board rows, in board order.
    pub board_util: Vec<BoardReport>,
    /// Σ all-CPU baselines of every tenant.
    pub cpu_total_s: f64,
    /// Σ per-tenant times under the fleet's admission decisions.
    pub fleet_total_s: f64,
    /// `cpu_total_s / fleet_total_s` — never below 1.0 by construction
    /// (only improving placements are admitted; everyone else runs the
    /// CPU baseline).
    pub aggregate_speedup: f64,
    /// Total reconfiguration hours the packing charged.
    pub reconfig_hours: f64,
    /// Canonical simulated automation hours (per-app searches, artifact
    /// derived, plus reconfiguration).
    pub sim_hours: f64,
    /// Canonical compile-lane hours (same contract as `sim_hours`).
    pub compile_hours: f64,
}

/// Assemble the report from the demand set and the packing.
/// `base_sim_hours` / `base_compile_hours` are the canonical automation
/// hours of the per-app searches (summed from their traces).
pub fn build(
    demands: &[TenantDemand],
    outcome: &PackOutcome,
    boards: usize,
    device: &Device,
    base_sim_hours: f64,
    base_compile_hours: f64,
) -> FleetReport {
    let mut apps = Vec::with_capacity(demands.len());
    let mut cpu_total_s = 0.0;
    let mut fleet_total_s = 0.0;
    let mut reconfig_s_total = 0.0;
    for (d, p) in demands.iter().zip(&outcome.placements) {
        cpu_total_s += d.cpu_time_s;
        let row = match p {
            Placement::Placed { board, option, reconfig_s } => {
                let opt = &d.options[*option];
                reconfig_s_total += *reconfig_s;
                fleet_total_s += opt.time_s;
                AppPlacement {
                    app_name: d.app_name.clone(),
                    status: FleetStatus::Placed { board: *board },
                    solution: opt.label.clone(),
                    kind: opt.kind.as_str(),
                    utilization: opt.utilization,
                    time_s: opt.time_s,
                    speedup: opt.speedup,
                    reconfig_s: *reconfig_s,
                }
            }
            other => {
                fleet_total_s += d.cpu_time_s;
                let status = match other {
                    Placement::Queued => FleetStatus::Queued,
                    Placement::Rejected => FleetStatus::Rejected,
                    _ => FleetStatus::Cpu,
                };
                AppPlacement {
                    app_name: d.app_name.clone(),
                    status,
                    solution: "all-CPU".to_string(),
                    kind: "cpu",
                    utilization: 0.0,
                    time_s: d.cpu_time_s,
                    speedup: 1.0,
                    reconfig_s: 0.0,
                }
            }
        };
        apps.push(row);
    }

    let board_util = outcome
        .boards
        .iter()
        .enumerate()
        .map(|(i, b)| BoardReport {
            board: i,
            // an idle board is unconfigured: it reports 0, not the BSP
            // static fraction a loaded bitstream would pin
            utilization: if b.tenants.is_empty() {
                0.0
            } else {
                device.utilization(&b.used)
            },
            resources: b.used,
            tenants: b.tenants.iter().map(|&t| demands[t].app_name.clone()).collect(),
        })
        .collect();

    let reconfig_hours = reconfig_s_total / 3600.0;
    FleetReport {
        boards,
        apps,
        board_util,
        cpu_total_s,
        fleet_total_s,
        aggregate_speedup: if fleet_total_s > 0.0 { cpu_total_s / fleet_total_s } else { 1.0 },
        reconfig_hours,
        sim_hours: base_sim_hours + reconfig_hours,
        compile_hours: base_compile_hours + reconfig_hours,
    }
}

impl FleetReport {
    /// Render the fleet table (byte-identical for any pool size and
    /// across warm cache re-runs).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "=== fleet placement: {} app(s) on {} Arria10 board(s) ===\n",
            self.apps.len(),
            self.boards
        ));
        out.push_str(&format!(
            "{:<12} {:<9} {:<10} {:>6} {:>8} {:>10}  {}\n",
            "app", "admission", "kind", "util", "speedup", "reconfig-h", "solution"
        ));
        for a in &self.apps {
            out.push_str(&format!(
                "{:<12} {:<9} {:<10} {:>6.3} {:>7.2}x {:>10.2}  {}\n",
                a.app_name,
                a.status.label(),
                a.kind,
                a.utilization,
                a.speedup,
                a.reconfig_s / 3600.0,
                a.solution
            ));
        }
        out.push_str("board utilization:\n");
        for b in &self.board_util {
            out.push_str(&format!(
                "  board {}: util {:.3}  tenants [{}]\n",
                b.board,
                b.utilization,
                b.tenants.join(", ")
            ));
        }
        out.push_str(&format!(
            "aggregate: all-CPU {:.5} s -> fleet {:.5} s  ({:.2}x vs all-CPU)\n",
            self.cpu_total_s, self.fleet_total_s, self.aggregate_speedup
        ));
        out.push_str(&format!(
            "reconfiguration charged: {:.2} h; automation time: {:.1} h simulated \
             ({:.1} compile-lane hours)\n",
            self.reconfig_hours, self.sim_hours, self.compile_hours
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::pack::first_fit_decreasing;
    use super::super::{PlacementKind, PlacementOption};
    use super::*;
    use crate::fpga::ARRIA10_GX;

    fn demand(name: &str, order: usize, frac: f64, speedup: f64) -> TenantDemand {
        let options = if speedup > 1.0 {
            vec![PlacementOption {
                label: format!("pattern L{order}"),
                kind: PlacementKind::Bitstream,
                utilization: ARRIA10_GX.bsp_frac + frac,
                resources: ARRIA10_GX.total.scale(frac),
                time_s: 1.0 / speedup,
                speedup,
                reconfig_s: 3.0 * 3600.0,
            }]
        } else {
            Vec::new()
        };
        TenantDemand { app_name: name.to_string(), order, cpu_time_s: 1.0, options }
    }

    #[test]
    fn aggregate_never_loses_to_all_cpu() {
        let demands = vec![
            demand("a", 0, 0.4, 3.0),
            demand("b", 1, 0.4, 2.0),
            demand("c", 2, 0.4, 1.5), // queued: only two boards' worth of room
            demand("d", 3, 0.0, 0.5), // stays on CPU
        ];
        let out = first_fit_decreasing(&demands, 2, 0.85, &ARRIA10_GX);
        let r = build(&demands, &out, 2, &ARRIA10_GX, 10.0, 8.0);
        assert!(r.aggregate_speedup >= 1.0, "aggregate {}", r.aggregate_speedup);
        assert_eq!(r.cpu_total_s, 4.0);
        // placed a and b contribute their measured times, c and d the CPU
        let expected = 1.0 / 3.0 + 1.0 / 2.0 + 1.0 + 1.0;
        assert!((r.fleet_total_s - expected).abs() < 1e-12);
        assert_eq!(r.apps.len(), 4);
        assert_eq!(r.apps[3].status, FleetStatus::Cpu);
        assert_eq!(r.apps[3].speedup, 1.0);
        assert!(r.sim_hours >= 10.0 && r.compile_hours >= 8.0);
    }

    #[test]
    fn report_renders_every_row() {
        let demands = vec![demand("a", 0, 0.3, 2.0), demand("b", 1, 0.0, 0.9)];
        let out = first_fit_decreasing(&demands, 1, 0.85, &ARRIA10_GX);
        let r = build(&demands, &out, 1, &ARRIA10_GX, 5.0, 4.0);
        let s = r.render();
        assert!(s.contains("fleet placement: 2 app(s) on 1 Arria10 board(s)"));
        assert!(s.contains("board 0"), "{s}");
        assert!(s.contains("all-CPU"), "{s}");
        assert!(s.contains("aggregate:"), "{s}");
    }
}
