//! Deterministic observability: a hierarchical span tracer and a
//! metrics registry threaded through every layer of the coordinator
//! (DESIGN.md §3i).
//!
//! Spans are stamped in **simulated** time read off the owning
//! [`crate::metrics::SimClock`], never the wall clock, so a trace is a
//! pure function of the inputs: byte-identical across `--pool 1/2/8`
//! and across repeated runs.  Metrics are counters, gauges, and summary
//! histograms keyed on interned [`Symbol`]s (PR-8 style — snapshot
//! ordering is the lexicographic `BTreeMap<Symbol, _>` order, and the
//! exporters only ever see spellings, never unstable symbol ids).
//!
//! Concurrency contract: `begin`/`end` span pairs are only issued from
//! single-threaded phases (a batch unit's private clock, or the shared
//! clock's sequential merge loop), so span order is deterministic.
//! Counter/gauge/histogram updates are commutative, so the parallel
//! phase may update them from worker threads without perturbing the
//! exported snapshot.  [`Recorder::merge_from`] folds a unit recorder
//! into the shared one *in submission order*, re-tracking the unit's
//! spans instead of rebasing timestamps (the Chrome exporter maps
//! tracks to pid rows).

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::intern::Symbol;

pub mod export;

/// A finished span: one named piece of work on a simulated timeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    /// What ran (e.g. `stage.analyze`, `compile fir_filter_L8_d4`).
    pub name: Symbol,
    /// Subsystem category (`pipeline`, `cache`, `clock.compile`, …).
    pub cat: Symbol,
    /// Start time, simulated seconds on the owning clock's timeline.
    pub start_s: f64,
    /// Duration, simulated seconds (0 for instant markers).
    pub dur_s: f64,
    /// Nesting depth when the span opened (0 = top level).
    pub depth: u32,
    /// Export track: 0 is the clock that recorded the span; a batch
    /// unit's spans are re-tracked to `1 + submission index` when
    /// merged into the shared recorder (Chrome `pid`).
    pub track: u32,
    /// Sub-track (Chrome `tid`): 0 for serial work, `1 + lane` for
    /// work charged to a compile lane (lane-occupancy timeline).
    pub lane: u32,
}

/// Handle for an in-flight span returned by [`Recorder::begin`]; hand
/// it back to [`Recorder::end`] when the work completes.
#[derive(Debug, Clone, Copy)]
pub struct OpenSpan {
    /// `(name, cat)`; `None` when the recorder is disabled.
    key: Option<(Symbol, Symbol)>,
    start_s: f64,
    depth: u32,
}

/// Summary histogram: count / sum / min / max of the observed values.
/// Merging two histograms is commutative, which keeps merged snapshots
/// independent of worker interleaving.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Histogram {
    /// Number of observations.
    pub count: u64,
    /// Sum of observed values.
    pub sum: f64,
    /// Smallest observed value (0 when empty).
    pub min: f64,
    /// Largest observed value (0 when empty).
    pub max: f64,
}

impl Histogram {
    fn observe(&mut self, v: f64) {
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            if v < self.min {
                self.min = v;
            }
            if v > self.max {
                self.max = v;
            }
        }
        self.count += 1;
        self.sum += v;
    }

    fn merge(&mut self, other: &Histogram) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            *self = *other;
            return;
        }
        self.count += other.count;
        self.sum += other.sum;
        if other.min < self.min {
            self.min = other.min;
        }
        if other.max > self.max {
            self.max = other.max;
        }
    }
}

#[derive(Default)]
struct Inner {
    spans: Vec<Span>,
    depth: u32,
    counters: BTreeMap<Symbol, u64>,
    gauges: BTreeMap<Symbol, f64>,
    hists: BTreeMap<Symbol, Histogram>,
}

/// The span + metrics sink.  One recorder lives inside every
/// [`crate::metrics::SimClock`]; a disabled recorder (see
/// [`crate::metrics::SimClock::new_untraced`]) turns every call into a
/// cheap no-op so the `obs_overhead` bench can price the tracing tax.
pub struct Recorder {
    enabled: bool,
    inner: Mutex<Inner>,
}

impl Recorder {
    /// A recorder; pass `enabled = false` for the no-op variant.
    pub fn new(enabled: bool) -> Self {
        Recorder {
            enabled,
            inner: Mutex::new(Inner::default()),
        }
    }

    /// Is this recorder collecting anything?
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Open a span at `start_s` (simulated seconds).  Nested opens
    /// record increasing depths; close with [`Recorder::end`].
    pub fn begin(&self, name: &str, cat: &str, start_s: f64) -> OpenSpan {
        if !self.enabled {
            return OpenSpan {
                key: None,
                start_s: 0.0,
                depth: 0,
            };
        }
        let key = (Symbol::intern(name), Symbol::intern(cat));
        let mut inner = self.inner.lock().unwrap();
        let depth = inner.depth;
        inner.depth += 1;
        OpenSpan {
            key: Some(key),
            start_s,
            depth,
        }
    }

    /// Close `span` at `end_s`, recording it on track 0 / lane 0.
    pub fn end(&self, span: OpenSpan, end_s: f64) {
        let Some((name, cat)) = span.key else {
            return;
        };
        let mut inner = self.inner.lock().unwrap();
        inner.depth = inner.depth.saturating_sub(1);
        inner.spans.push(Span {
            name,
            cat,
            start_s: span.start_s,
            dur_s: (end_s - span.start_s).max(0.0),
            depth: span.depth,
            track: 0,
            lane: 0,
        });
    }

    /// Record a complete span in one call (used by the clock charges,
    /// which know both endpoints; `lane` picks the Chrome sub-track).
    pub fn record(&self, name: Symbol, cat: &str, start_s: f64, dur_s: f64, lane: u32) {
        if !self.enabled {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        let depth = inner.depth;
        inner.spans.push(Span {
            name,
            cat: Symbol::intern(cat),
            start_s,
            dur_s,
            depth,
            track: 0,
            lane,
        });
    }

    /// Record an instant (zero-duration) marker span at `at_s`.
    pub fn mark(&self, name: &str, cat: &str, at_s: f64) {
        if !self.enabled {
            return;
        }
        let sym = Symbol::intern(name);
        self.record(sym, cat, at_s, 0.0, 0);
    }

    /// Add `delta` to the counter `name`.
    pub fn count(&self, name: &str, delta: u64) {
        if !self.enabled {
            return;
        }
        let sym = Symbol::intern(name);
        let mut inner = self.inner.lock().unwrap();
        *inner.counters.entry(sym).or_insert(0) += delta;
    }

    /// Set the gauge `name` to `value` (merges take the maximum, so
    /// merged snapshots stay order-independent).
    pub fn gauge(&self, name: &str, value: f64) {
        if !self.enabled {
            return;
        }
        let sym = Symbol::intern(name);
        let mut inner = self.inner.lock().unwrap();
        inner.gauges.insert(sym, value);
    }

    /// Fold `value` into the summary histogram `name`.
    pub fn observe(&self, name: &str, value: f64) {
        if !self.enabled {
            return;
        }
        let sym = Symbol::intern(name);
        let mut inner = self.inner.lock().unwrap();
        inner.hists.entry(sym).or_default().observe(value);
    }

    /// Fold a unit recorder into this one.  Spans are appended in the
    /// unit's own order with their track rewritten to `track` (call in
    /// submission order for deterministic span logs); counters add,
    /// gauges take the max, histograms merge — all commutative.
    pub fn merge_from(&self, other: &Recorder, track: u32) {
        if !self.enabled || !other.enabled {
            return;
        }
        let theirs = {
            let o = other.inner.lock().unwrap();
            (
                o.spans.clone(),
                o.counters.clone(),
                o.gauges.clone(),
                o.hists.clone(),
            )
        };
        let mut inner = self.inner.lock().unwrap();
        for mut s in theirs.0 {
            s.track = track;
            inner.spans.push(s);
        }
        for (k, v) in theirs.1 {
            *inner.counters.entry(k).or_insert(0) += v;
        }
        for (k, v) in theirs.2 {
            let g = inner.gauges.entry(k).or_insert(v);
            if v > *g {
                *g = v;
            }
        }
        for (k, v) in theirs.3 {
            inner.hists.entry(k).or_default().merge(&v);
        }
    }

    /// Snapshot of the finished spans, in recorded order.
    pub fn spans(&self) -> Vec<Span> {
        self.inner.lock().unwrap().spans.clone()
    }

    /// Snapshot of the counters (lexicographic by spelling).
    pub fn counters(&self) -> BTreeMap<Symbol, u64> {
        self.inner.lock().unwrap().counters.clone()
    }

    /// Snapshot of the gauges (lexicographic by spelling).
    pub fn gauges(&self) -> BTreeMap<Symbol, f64> {
        self.inner.lock().unwrap().gauges.clone()
    }

    /// Snapshot of the histograms (lexicographic by spelling).
    pub fn histograms(&self) -> BTreeMap<Symbol, Histogram> {
        self.inner.lock().unwrap().hists.clone()
    }

    /// Current value of one counter (0 when never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        let sym = Symbol::intern(name);
        self.inner
            .lock()
            .unwrap()
            .counters
            .get(&sym)
            .copied()
            .unwrap_or(0)
    }
}

impl std::fmt::Debug for Recorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.inner.lock().unwrap();
        f.debug_struct("Recorder")
            .field("enabled", &self.enabled)
            .field("spans", &inner.spans.len())
            .field("counters", &inner.counters.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_nest_and_record_depth() {
        let r = Recorder::new(true);
        let outer = r.begin("outer", "test", 0.0);
        let inner = r.begin("inner", "test", 1.0);
        r.end(inner, 2.0);
        r.end(outer, 3.0);
        let spans = r.spans();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].name, "inner");
        assert_eq!(spans[0].depth, 1);
        assert_eq!(spans[0].dur_s, 1.0);
        assert_eq!(spans[1].name, "outer");
        assert_eq!(spans[1].depth, 0);
        assert_eq!(spans[1].dur_s, 3.0);
    }

    #[test]
    fn disabled_recorder_is_inert() {
        let r = Recorder::new(false);
        let s = r.begin("x", "test", 0.0);
        r.end(s, 5.0);
        r.count("c", 3);
        r.observe("h", 1.0);
        assert!(r.spans().is_empty());
        assert_eq!(r.counter("c"), 0);
        assert!(r.histograms().is_empty());
    }

    #[test]
    fn merge_is_commutative_on_metrics() {
        let a = Recorder::new(true);
        let b = Recorder::new(true);
        a.count("c", 2);
        b.count("c", 3);
        a.observe("h", 1.0);
        b.observe("h", 5.0);
        a.gauge("g", 2.0);
        b.gauge("g", 7.0);

        let ab = Recorder::new(true);
        ab.merge_from(&a, 1);
        ab.merge_from(&b, 2);
        let ba = Recorder::new(true);
        ba.merge_from(&b, 2);
        ba.merge_from(&a, 1);

        assert_eq!(ab.counter("c"), 5);
        assert_eq!(ba.counter("c"), 5);
        assert_eq!(ab.histograms(), ba.histograms());
        assert_eq!(ab.gauges(), ba.gauges());
        let h = ab.histograms();
        let (_, hist) = h.iter().next().unwrap();
        assert_eq!(hist.count, 2);
        assert_eq!(hist.min, 1.0);
        assert_eq!(hist.max, 5.0);
    }

    #[test]
    fn merge_retracks_spans_in_submission_order() {
        let unit = Recorder::new(true);
        let s = unit.begin("work", "test", 0.0);
        unit.end(s, 1.0);
        let shared = Recorder::new(true);
        shared.merge_from(&unit, 4);
        let spans = shared.spans();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].track, 4);
    }
}
