//! Exporters for the [`Recorder`]: a JSON-lines span log, Chrome
//! `trace_event` JSON (load it at `chrome://tracing` or in Perfetto),
//! and a Prometheus-style text metrics snapshot.
//!
//! All output is derived from symbol *spellings* and simulated times —
//! never wall time or symbol ids — so the bytes are deterministic
//! across runs, pool sizes, and interning order.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::cache::CacheStats;
use crate::util::intern::Symbol;
use crate::util::json::{self, Json};

use super::{Recorder, Span};

fn span_obj(s: &Span) -> Json {
    let mut o = BTreeMap::new();
    o.insert("name".to_string(), Json::Str(s.name.as_str().to_string()));
    o.insert("cat".to_string(), Json::Str(s.cat.as_str().to_string()));
    o.insert("start_s".to_string(), Json::Num(s.start_s));
    o.insert("dur_s".to_string(), Json::Num(s.dur_s));
    o.insert("depth".to_string(), Json::Num(f64::from(s.depth)));
    o.insert("track".to_string(), Json::Num(f64::from(s.track)));
    o.insert("lane".to_string(), Json::Num(f64::from(s.lane)));
    Json::Obj(o)
}

/// Render the span log as JSON lines: one object per span, in recorded
/// (for batch runs: submission-merge) order.
pub fn render_jsonl(rec: &Recorder) -> String {
    let mut out = String::new();
    for s in rec.spans() {
        out.push_str(&json::to_string(&span_obj(&s)));
        out.push('\n');
    }
    out
}

/// Render the span log in Chrome `trace_event` format: complete
/// (`ph: "X"`) events with microsecond timestamps, `pid` = span track
/// (0 = shared clock, `1 + i` = batch unit `i`) and `tid` = lane
/// (0 = serial timeline, `1 + l` = compile lane `l`).
pub fn render_chrome(rec: &Recorder) -> String {
    let mut events = Vec::new();
    for s in rec.spans() {
        let mut e = BTreeMap::new();
        e.insert("ph".to_string(), Json::Str("X".to_string()));
        e.insert("name".to_string(), Json::Str(s.name.as_str().to_string()));
        e.insert("cat".to_string(), Json::Str(s.cat.as_str().to_string()));
        e.insert("ts".to_string(), Json::Num(s.start_s * 1e6));
        e.insert("dur".to_string(), Json::Num(s.dur_s * 1e6));
        e.insert("pid".to_string(), Json::Num(f64::from(s.track)));
        e.insert("tid".to_string(), Json::Num(f64::from(s.lane)));
        let mut args = BTreeMap::new();
        args.insert("depth".to_string(), Json::Num(f64::from(s.depth)));
        e.insert("args".to_string(), Json::Obj(args));
        events.push(Json::Obj(e));
    }
    let mut doc = BTreeMap::new();
    doc.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    doc.insert("traceEvents".to_string(), Json::Arr(events));
    json::to_string(&Json::Obj(doc))
}

/// `cache.misses` → `flopt_cache_misses`.
fn metric_name(spelling: &str) -> String {
    let mut n = String::from("flopt_");
    n.extend(
        spelling
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' }),
    );
    n
}

/// Deterministic number rendering shared with `util::json`: integral
/// values print without a fractional part.
fn fmt_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Render the metrics snapshot as Prometheus-style text.  `cache`
/// folds the store's [`CacheStats`] (hits, misses, evictions,
/// corrupt-entry recomputes) into the counter section at export time,
/// so the store's own counting stays untouched.  Ordering is the
/// lexicographic `BTreeMap<Symbol, _>` order — byte-identical across
/// pool sizes and runs.
pub fn render_prometheus(rec: &Recorder, cache: Option<&CacheStats>) -> String {
    let mut counters = rec.counters();
    if let Some(c) = cache {
        for (name, v) in [
            ("cache.corrupt_recomputes", c.corrupt_recomputes()),
            ("cache.disk_hits", c.disk_hits),
            ("cache.disk_read_errors", c.disk_read_errors),
            ("cache.disk_rejects", c.disk_rejects),
            ("cache.evictions_lru", c.lru_evictions),
            ("cache.evictions_ttl", c.ttl_evictions),
            ("cache.mem_hits", c.mem_hits),
            ("cache.misses", c.misses),
        ] {
            *counters.entry(Symbol::intern(name)).or_insert(0) += v;
        }
    }
    let mut out = String::from("# flopt metrics snapshot (deterministic, simulated time)\n");
    for (k, v) in &counters {
        let n = metric_name(k.as_str());
        let _ = writeln!(out, "# TYPE {n} counter\n{n} {v}");
    }
    for (k, v) in rec.gauges() {
        let n = metric_name(k.as_str());
        let _ = writeln!(out, "# TYPE {n} gauge\n{n} {}", fmt_value(v));
    }
    for (k, h) in rec.histograms() {
        let n = metric_name(k.as_str());
        let _ = writeln!(out, "# TYPE {n} summary");
        let _ = writeln!(out, "{n}_count {}", h.count);
        let _ = writeln!(out, "{n}_sum {}", fmt_value(h.sum));
        let _ = writeln!(out, "{n}_min {}", fmt_value(h.min));
        let _ = writeln!(out, "{n}_max {}", fmt_value(h.max));
    }
    out
}

/// Write the span log to `path`; `.json` extension selects the Chrome
/// `trace_event` format, anything else the JSON-lines log.
pub fn write_trace(path: &str, rec: &Recorder) -> std::io::Result<()> {
    let body = if path.ends_with(".json") {
        render_chrome(rec)
    } else {
        render_jsonl(rec)
    };
    std::fs::write(path, body)
}

/// Write the Prometheus-style metrics snapshot to `path`.
pub fn write_metrics(path: &str, rec: &Recorder, cache: Option<&CacheStats>) -> std::io::Result<()> {
    std::fs::write(path, render_prometheus(rec, cache))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Recorder {
        let r = Recorder::new(true);
        let s = r.begin("stage.analyze", "pipeline", 0.0);
        r.end(s, 30.0);
        r.count("cache.miss.trace", 1);
        r.gauge("serve.active_tenants", 4.0);
        r.observe("pool.map_batch", 3.0);
        r
    }

    #[test]
    fn jsonl_is_one_parseable_object_per_span() {
        let r = sample();
        let text = render_jsonl(&r);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 1);
        let v = json::parse(lines[0]).expect("jsonl line parses");
        match v {
            Json::Obj(o) => {
                assert_eq!(o.get("name"), Some(&Json::Str("stage.analyze".into())));
                assert_eq!(o.get("dur_s"), Some(&Json::Num(30.0)));
            }
            other => panic!("expected object, got {other:?}"),
        }
    }

    #[test]
    fn chrome_trace_is_wellformed() {
        let r = sample();
        let v = json::parse(&render_chrome(&r)).expect("chrome trace parses");
        let Json::Obj(o) = v else {
            panic!("expected object")
        };
        let Some(Json::Arr(events)) = o.get("traceEvents") else {
            panic!("missing traceEvents")
        };
        assert_eq!(events.len(), 1);
        let Json::Obj(e) = &events[0] else {
            panic!("expected event object")
        };
        assert_eq!(e.get("ph"), Some(&Json::Str("X".into())));
        assert_eq!(e.get("ts"), Some(&Json::Num(0.0)));
        assert_eq!(e.get("dur"), Some(&Json::Num(30.0 * 1e6)));
    }

    #[test]
    fn prometheus_folds_cache_stats() {
        let r = sample();
        let stats = CacheStats {
            mem_hits: 2,
            disk_hits: 1,
            misses: 3,
            disk_rejects: 1,
            disk_read_errors: 1,
            ttl_evictions: 0,
            lru_evictions: 4,
        };
        let text = render_prometheus(&r, Some(&stats));
        assert!(text.contains("flopt_cache_mem_hits 2\n"));
        assert!(text.contains("flopt_cache_corrupt_recomputes 2\n"));
        assert!(text.contains("flopt_cache_evictions_lru 4\n"));
        assert!(text.contains("flopt_cache_miss_trace 1\n"));
        assert!(text.contains("flopt_serve_active_tenants 4\n"));
        assert!(text.contains("flopt_pool_map_batch_count 1\n"));
        assert!(text.contains("flopt_pool_map_batch_sum 3\n"));
        // counters precede gauges precede histograms, each sorted
        let c = text.find("flopt_cache_corrupt_recomputes").unwrap();
        let g = text.find("flopt_serve_active_tenants").unwrap();
        let h = text.find("flopt_pool_map_batch_count").unwrap();
        assert!(c < g && g < h);
    }
}
