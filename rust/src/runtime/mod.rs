//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! This is the only place the L3 coordinator touches XLA.  Python never
//! runs here — artifacts are compiled once at build time (`make
//! artifacts`) and the manifest + HLO text are all the rust binary needs.
//!
//! Interchange is HLO **text** (xla_extension 0.5.1 rejects jax ≥0.5
//! serialized protos with 64-bit instruction ids; the text parser
//! reassigns ids).
//!
//! The XLA bindings are an **optional** dependency gated behind the
//! `xla` cargo feature: default builds compile against an inert stub so
//! the whole crate (and every search path) works without the vendored
//! `xla` crate closure.  Stub builds still parse `manifest.json`; they
//! fail with a clear error when a PJRT client is actually constructed.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context};

use crate::util::json;

#[cfg(not(feature = "xla"))]
use self::xla_stub as xla;

// The feature only declares intent; the crate itself is not shipped in
// this repository.  Wiring it up means vendoring the `xla` crate closure,
// adding the optional dependency (`xla = { path = ..., optional = true }`
// plus `xla = ["dep:xla"]` in `[features]`), and deleting this guard.
#[cfg(feature = "xla")]
compile_error!(
    "the `xla` feature requires vendoring the xla crate closure; \
     see rust/src/runtime/mod.rs and DESIGN.md §2"
);

/// Inert stand-in for the `xla` crate (the vendored closure is not part
/// of this repository).  Mirrors the API surface [`crate::runtime::Runtime`]
/// uses; every entry point fails at client construction time.
#[cfg(not(feature = "xla"))]
mod xla_stub {
    use std::path::Path;

    /// Error type matching the shape of `xla::Error` call sites expect.
    #[derive(Debug)]
    pub struct Error(pub &'static str);

    const NO_XLA: &str =
        "flopt was built without the `xla` feature; PJRT execution is unavailable";

    /// PJRT client handle (stub).
    pub struct PjRtClient;

    impl PjRtClient {
        /// Always fails in stub builds.
        pub fn cpu() -> Result<Self, Error> {
            Err(Error(NO_XLA))
        }

        /// Unreachable in stub builds (no client can exist).
        pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
            Err(Error(NO_XLA))
        }
    }

    /// Compiled executable handle (stub).
    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        /// Unreachable in stub builds.
        pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
            Err(Error(NO_XLA))
        }
    }

    /// Device buffer handle (stub).
    pub struct PjRtBuffer;

    impl PjRtBuffer {
        /// Unreachable in stub builds.
        pub fn to_literal_sync(&self) -> Result<Literal, Error> {
            Err(Error(NO_XLA))
        }
    }

    /// HLO module proto handle (stub).
    pub struct HloModuleProto;

    impl HloModuleProto {
        /// Always fails in stub builds.
        pub fn from_text_file(_p: impl AsRef<Path>) -> Result<Self, Error> {
            Err(Error(NO_XLA))
        }
    }

    /// XLA computation handle (stub).
    pub struct XlaComputation;

    impl XlaComputation {
        /// Trivially constructible; compiling it fails.
        pub fn from_proto(_p: &HloModuleProto) -> Self {
            XlaComputation
        }
    }

    /// Host literal handle (stub).
    pub struct Literal;

    impl Literal {
        /// Trivially constructible; executing with it fails.
        pub fn vec1(_data: &[f32]) -> Self {
            Literal
        }

        /// Reshape is a no-op on the stub literal.
        pub fn reshape(self, _dims: &[i64]) -> Result<Self, Error> {
            Ok(self)
        }

        /// Unreachable in stub builds.
        pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
            Err(Error(NO_XLA))
        }

        /// Unreachable in stub builds.
        pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
            Err(Error(NO_XLA))
        }
    }
}

/// I/O signature of one artifact (from `manifest.json`).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Artifact name (manifest key).
    pub name: String,
    /// HLO text file, relative to the artifact dir.
    pub file: String,
    /// input shapes (all f32, rank-1 for the paper workloads)
    pub input_shapes: Vec<Vec<usize>>,
    /// Number of outputs in the result tuple.
    pub num_outputs: usize,
}

/// PJRT CPU client + compiled-executable cache over an artifact dir.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    specs: HashMap<String, ArtifactSpec>,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open the artifact directory (reads `manifest.json`).
    pub fn load(dir: impl AsRef<Path>) -> crate::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let doc = json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let mut specs = HashMap::new();
        for (name, entry) in doc.as_obj().ok_or_else(|| anyhow!("manifest must be an object"))? {
            let file = entry
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| anyhow!("artifact `{name}`: missing file"))?
                .to_string();
            let input_shapes = entry
                .get("inputs")
                .and_then(|i| i.as_arr())
                .ok_or_else(|| anyhow!("artifact `{name}`: missing inputs"))?
                .iter()
                .map(|inp| {
                    inp.get("shape")
                        .and_then(|s| s.as_arr())
                        .map(|dims| dims.iter().filter_map(|d| d.as_usize()).collect())
                        .ok_or_else(|| anyhow!("artifact `{name}`: bad shape"))
                })
                .collect::<Result<Vec<Vec<usize>>, _>>()?;
            let num_outputs = entry
                .get("num_outputs")
                .and_then(|n| n.as_usize())
                .ok_or_else(|| anyhow!("artifact `{name}`: missing num_outputs"))?;
            specs.insert(
                name.clone(),
                ArtifactSpec { name: name.clone(), file, input_shapes, num_outputs },
            );
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client, dir, specs, cache: Mutex::new(HashMap::new()) })
    }

    /// Artifact names available.
    pub fn artifact_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.specs.keys().cloned().collect();
        v.sort();
        v
    }

    /// I/O signature of one artifact.
    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    fn executable(
        &self,
        name: &str,
    ) -> crate::Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().expect("poisoned").get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .specs
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact `{name}`"))?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling `{name}`: {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .expect("poisoned")
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with f32 inputs; returns the flattened f32
    /// outputs.  Input lengths must match the manifest shapes.
    pub fn execute_f32(&self, name: &str, inputs: &[Vec<f32>]) -> crate::Result<Vec<Vec<f32>>> {
        let spec = self
            .specs
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact `{name}`"))?
            .clone();
        if inputs.len() != spec.input_shapes.len() {
            bail!(
                "`{name}` expects {} inputs, got {}",
                spec.input_shapes.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, shape)) in inputs.iter().zip(&spec.input_shapes).enumerate() {
            let want: usize = shape.iter().product();
            if data.len() != want {
                bail!("`{name}` input {i}: expected {want} elements, got {}", data.len());
            }
            let lit = xla::Literal::vec1(data);
            let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
            let lit = lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))?;
            literals.push(lit);
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing `{name}`: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: output is always a tuple
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        if parts.len() != spec.num_outputs {
            bail!("`{name}`: expected {} outputs, got {}", spec.num_outputs, parts.len());
        }
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

/// Default artifact dir: `$FLOPT_ARTIFACTS` or `artifacts/` under the
/// crate root (where `make artifacts` writes).
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(d) = std::env::var("FLOPT_ARTIFACTS") {
        return PathBuf::from(d);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full end-to-end numerics live in rust/tests/runtime_artifacts.rs
    // (they need `make artifacts`).  Here: manifest parsing only.

    #[test]
    fn manifest_parse_errors_are_reported() {
        let dir = std::env::temp_dir().join("flopt-runtime-test");
        let _ = std::fs::create_dir_all(&dir);
        std::fs::write(dir.join("manifest.json"), "{ not json").unwrap();
        let err = match Runtime::load(&dir) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("bad manifest must fail"),
        };
        assert!(err.contains("manifest"), "{err}");
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let err = match Runtime::load("/nonexistent-dir-xyz") {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("missing dir must fail"),
        };
        assert!(err.contains("make artifacts"));
    }

    #[test]
    fn default_dir_is_stable() {
        let d = default_artifact_dir();
        assert!(d.ends_with("artifacts"));
    }
}
